//! Bench: real wall-clock of the threaded shared-nothing substrate —
//! TD-Orch vs direct-push vs direct-pull on a Zipf(1.0)-hotspot YCSB
//! batch, on ≥ 4 real OS worker threads.  Every run is validated against
//! `sequential_reference` before its time is reported.
//! `cargo bench --bench exec_wallclock`.

mod bench_util;

use bench_util::Bench;
use tdorch::baselines::{DirectPull, DirectPush};
use tdorch::exec::ThreadedCluster;
use tdorch::kvstore::{normalized_snapshot, preload, Bucket, KvApp};
use tdorch::orchestration::tdorch::TdOrch;
use tdorch::orchestration::Scheduler;
use tdorch::repro::exec::{hotspot_workload, BUCKETS, N_PRELOAD};
use tdorch::DistStore;

const GAMMA: f64 = 1.0;
const PER_MACHINE: usize = 20_000;

fn main() {
    let b = Bench::new("exec_wallclock");
    let app = KvApp::new(BUCKETS);

    for p in [4usize, 8] {
        // Exactly the workload + oracle `repro exec` runs and validates.
        let (tasks, expected) = hotspot_workload(p, PER_MACHINE, GAMMA, 7);

        let td = TdOrch::new();
        let scheds: [(&str, &dyn Scheduler<KvApp, ThreadedCluster>); 3] = [
            ("td-orch", &td),
            ("direct-push", &DirectPush),
            ("direct-pull", &DirectPull),
        ];
        const ITERS: usize = 3;
        let mut max_busy = [0.0f64; 3];
        for (i, (name, sched)) in scheds.into_iter().enumerate() {
            // Preload and task cloning stay OUTSIDE the timed closure so
            // the reported wall time is the scheduler stage alone; store
            // validation runs after timing, on every iteration's output.
            let mut prepared: Vec<_> = (0..ITERS)
                .map(|_| {
                    let mut store: DistStore<Bucket> = DistStore::new(p);
                    preload(&mut store, BUCKETS, N_PRELOAD);
                    (ThreadedCluster::new(p), store, tasks.clone())
                })
                .collect();
            let mut finished: Vec<DistStore<Bucket>> = Vec::with_capacity(ITERS);
            let mut last_max = 0.0f64;
            b.run(&format!("{name}-P{p}x{PER_MACHINE}"), ITERS, || {
                let (mut cluster, mut store, batch) =
                    prepared.pop().expect("one prepared run per iter");
                let outcome = sched.run_stage(&mut cluster, &app, batch, &mut store);
                last_max = cluster.max_busy_ms();
                finished.push(store);
                outcome.total_executed
            });
            for store in &finished {
                assert_eq!(
                    normalized_snapshot(store),
                    expected,
                    "{name}: threaded store != sequential_reference"
                );
            }
            println!("    max-loaded machine busy: {last_max:.2} ms");
            max_busy[i] = last_max;
        }
        println!(
            "    P={p}: td-orch max-machine speedup: {:.2}x vs direct-push, {:.2}x vs direct-pull",
            max_busy[1] / max_busy[0],
            max_busy[2] / max_busy[0],
        );
    }
}
