//! Bench: the latency-vs-offered-load sweep on the pipelined server —
//! wall-clock per sweep point, sim vs threaded backend, plus the
//! deterministic schedule columns (goodput/tick, rejection rate, wait
//! percentiles), which the bench ASSERTS are identical across backends
//! point by point (the logical service clock is ledger-superstep-driven,
//! so the queueing dynamics must not depend on the backend).  Engine
//! construction (ingestion, relay trees, pool spawn) stays outside the
//! timed region.  `cargo bench --bench loadcurve`.

mod bench_util;

use bench_util::Bench;
use tdorch::exec::ThreadedCluster;
use tdorch::graph::flags::Flags;
use tdorch::graph::gen;
use tdorch::graph::ingest::ingestions;
use tdorch::graph::spmd::{ingest_once, Placement, SpmdEngine};
use tdorch::serve::{QueryShard, RunOpts, ServeConfig, ServeReport, Server};
use tdorch::workload::{
    generate_stream, hot_source_order, ClosedLoop, ClosedLoopConfig, OpenLoopSource, QueryMix,
    StreamConfig,
};
use tdorch::{Cluster, CostModel};

const QUERIES: usize = 48;
const P: usize = 8;
/// (per_tick, every_ticks): offered rates from 1/8 to 4 queries/tick.
const RATES: [(usize, u64); 5] = [(1, 8), (1, 2), (1, 1), (2, 1), (4, 1)];
const CLIENTS: [usize; 3] = [2, 8, 32];

fn cfg() -> ServeConfig {
    ServeConfig { batch: 4, queue_cap: 8, ..ServeConfig::default() }
}

fn schedule_line(label: &str, rep: &ServeReport) {
    let (w50, _, w99) = rep.wait_tick_percentiles();
    let (st50, _, st99) = rep.service_tick_percentiles();
    println!(
        "    {label}: offered {} -> served {} (rejection {:.3}), goodput {:.4}/tick \
         over {} ticks; wait p50 {w50:.0} / p99 {w99:.0}, service p50 {st50:.0} / \
         p99 {st99:.0} ticks; wall {:.1} ms",
        rep.offered(),
        rep.served(),
        rep.rejection_rate(),
        rep.goodput_per_tick(),
        rep.ticks,
        rep.wall_ms,
    );
}

fn assert_schedules_match(point: &str, sim: &ServeReport, thr: &ServeReport) {
    assert_eq!(sim.served(), thr.served(), "{point}: served diverged");
    assert_eq!(sim.rejected, thr.rejected, "{point}: rejections diverged");
    assert_eq!(sim.batches, thr.batches, "{point}: batch count diverged");
    assert_eq!(sim.ticks, thr.ticks, "{point}: logical span diverged");
    for (a, b) in sim.results.iter().zip(&thr.results) {
        assert_eq!(a.id, b.id, "{point}: dispatch order diverged");
        assert_eq!(a.wait_ticks, b.wait_ticks, "{point}: query {} wait diverged", a.id);
        assert_eq!(
            a.service_ticks, b.service_ticks,
            "{point}: query {} service ticks diverged",
            a.id
        );
        assert_eq!(a.bits, b.bits, "{point}: query {} bits diverged", a.id);
    }
}

fn main() {
    let b = Bench::new("loadcurve");
    let g = gen::barabasi_albert(10_000, 6, 7);
    let cost = CostModel::paper_cluster();
    let ing0 = ingestions();
    println!(
        "BA graph n={} m={}, P={P}, {QUERIES}-query balanced mix per open-loop point, zipf 1.5",
        g.n,
        g.m()
    );

    let dg = ingest_once(&g, P, cost, Placement::Spread);
    let hot = hot_source_order(&dg.out_deg);
    let mut sim = Server::new(
        SpmdEngine::from_ingested(
            Cluster::new(P, cost),
            dg.clone(),
            cost,
            Flags::tdo_gp(),
            "loadcurve-sim",
            QueryShard::new,
        ),
        cfg(),
    );
    let mut thr = Server::new(
        SpmdEngine::from_ingested(
            ThreadedCluster::new(P),
            dg,
            cost,
            Flags::tdo_gp(),
            "loadcurve-threaded",
            QueryShard::new,
        ),
        cfg(),
    );

    for (per_tick, every_ticks) in RATES {
        let scfg = StreamConfig {
            queries: QUERIES,
            per_tick,
            every_ticks,
            zipf_s: 1.5,
            mix: QueryMix::balanced(),
        };
        let stream = generate_stream(scfg, &hot, 42);
        let point = format!("open-{:.3}qpt", scfg.offered_per_tick());
        let mut rep_sim: Option<ServeReport> = None;
        b.run(&format!("{point}-sim"), 1, || {
            let rep = sim.serve(&mut OpenLoopSource::new(&stream), RunOpts::default());
            let n = rep.served();
            rep_sim = Some(rep);
            n
        });
        let mut rep_thr: Option<ServeReport> = None;
        b.run(&format!("{point}-threaded"), 1, || {
            let rep = thr.serve(&mut OpenLoopSource::new(&stream), RunOpts::default());
            let n = rep.served();
            rep_thr = Some(rep);
            n
        });
        let rep_sim = rep_sim.expect("sim point ran");
        let rep_thr = rep_thr.expect("threaded point ran");
        schedule_line("sim     ", &rep_sim);
        schedule_line("threaded", &rep_thr);
        assert_schedules_match(&point, &rep_sim, &rep_thr);
    }

    for clients in CLIENTS {
        let ccfg = ClosedLoopConfig {
            clients,
            think_ticks: 4,
            queries_per_client: 4,
            zipf_s: 1.5,
            mix: QueryMix::balanced(),
        };
        let point = format!("closed-{clients}c");
        let mut rep_sim: Option<ServeReport> = None;
        b.run(&format!("{point}-sim"), 1, || {
            let mut src = ClosedLoop::new(ccfg, &hot, 42);
            let rep = sim.serve(&mut src, RunOpts::default());
            let n = rep.served();
            rep_sim = Some(rep);
            n
        });
        let mut rep_thr: Option<ServeReport> = None;
        b.run(&format!("{point}-threaded"), 1, || {
            let mut src = ClosedLoop::new(ccfg, &hot, 42);
            let rep = thr.serve(&mut src, RunOpts::default());
            let n = rep.served();
            rep_thr = Some(rep);
            n
        });
        let rep_sim = rep_sim.expect("sim point ran");
        let rep_thr = rep_thr.expect("threaded point ran");
        schedule_line("sim     ", &rep_sim);
        schedule_line("threaded", &rep_thr);
        assert_schedules_match(&point, &rep_sim, &rep_thr);
    }

    println!(
        "\npool: {} threads, {} epochs over the whole sweep",
        thr.engine().sub().pool_threads(),
        thr.engine().sub().epochs(),
    );
    let ingested = ingestions() - ing0;
    assert_eq!(ingested, 1, "the whole sweep must ingest exactly once");
    println!("ingestions: {ingested} (shared by both backends and every point)");
}
