//! Property: every scheduler (TD-Orch and all three §2.3 baselines)
//! produces a store identical to the sequential oracle, for arbitrary
//! workloads — uniform, skewed, adversarial single-key, cross-address
//! writes — across machine counts and TD-Orch (F, C) parameter choices.

mod common;

use common::{for_seeds, random_tasks, CounterApp, MaxApp};
use tdorch::baselines::{DirectPull, DirectPush, SortingBased};
use tdorch::orchestration::tdorch::TdOrch;
use tdorch::orchestration::{sequential_reference, spread_tasks, Scheduler, Task};
use tdorch::rng::Rng;
use tdorch::{Cluster, CostModel, DistStore};

fn check_counter<S: Scheduler<CounterApp>>(
    sched: &S,
    p: usize,
    tasks: Vec<Task<i64>>,
    label: &str,
) {
    let app = CounterApp;
    let spread = spread_tasks(tasks, p);

    let mut expected: DistStore<i64> = DistStore::new(p);
    sequential_reference(&app, &spread, &mut expected);

    let mut cluster = Cluster::new(p, CostModel::paper_cluster());
    let mut store: DistStore<i64> = DistStore::new(p);
    let outcome = sched.run_stage(&mut cluster, &app, spread.clone(), &mut store);

    assert_eq!(
        store.snapshot(),
        expected.snapshot(),
        "{label}: store mismatch (p={p})"
    );
    let n: u64 = spread.iter().map(|b| b.len() as u64).sum();
    assert_eq!(outcome.total_executed, n, "{label}: executed {}",
        outcome.total_executed);
}

fn all_schedulers_match(p: usize, tasks: Vec<Task<i64>>) {
    check_counter(&TdOrch::new(), p, tasks.clone(), "td-orch");
    check_counter(&DirectPull, p, tasks.clone(), "direct-pull");
    check_counter(&DirectPush, p, tasks.clone(), "direct-push");
    check_counter(&SortingBased, p, tasks, "sorting");
}

#[test]
fn uniform_workload_all_schedulers() {
    for_seeds(5, |seed| {
        let mut rng = Rng::new(seed);
        let tasks = random_tasks(&mut rng, 500, 200, 0.0, false);
        for p in [1, 2, 4, 8] {
            all_schedulers_match(p, tasks.clone());
        }
    });
}

#[test]
fn skewed_workload_all_schedulers() {
    for_seeds(5, |seed| {
        let mut rng = Rng::new(100 + seed);
        let tasks = random_tasks(&mut rng, 600, 150, 0.7, false);
        for p in [2, 7, 16] {
            all_schedulers_match(p, tasks.clone());
        }
    });
}

#[test]
fn adversarial_single_key() {
    // All n tasks hit one chunk — the worst case of §2.3.
    for p in [1, 2, 8, 16] {
        let tasks: Vec<Task<i64>> = (0..400).map(|i| Task::inplace(7, i % 5 + 1)).collect();
        all_schedulers_match(p, tasks);
    }
}

#[test]
fn cross_address_writes() {
    for_seeds(5, |seed| {
        let mut rng = Rng::new(200 + seed);
        let tasks = random_tasks(&mut rng, 500, 100, 0.4, true);
        for p in [2, 8] {
            all_schedulers_match(p, tasks.clone());
        }
    });
}

#[test]
fn tdorch_parameter_sweep() {
    // TD-Orch must be correct for any (F, C), not just the defaults.
    for_seeds(3, |seed| {
        let mut rng = Rng::new(300 + seed);
        let tasks = random_tasks(&mut rng, 400, 80, 0.6, true);
        for p in [4, 16] {
            for fanout in [2, 3, 8] {
                for c in [2, 4, 32] {
                    check_counter(
                        &TdOrch::with_params(fanout, c),
                        p,
                        tasks.clone(),
                        &format!("td-orch F={fanout} C={c}"),
                    );
                }
            }
        }
    });
}

#[test]
fn max_app_idempotent_merge() {
    let app = MaxApp;
    for p in [1, 4, 9] {
        let tasks: Vec<Task<u64>> = (0..300)
            .map(|i| Task::new(i % 50, (i * 7) % 50, i * 31 % 1000))
            .collect();
        let spread = spread_tasks(tasks, p);
        let mut expected: DistStore<u64> = DistStore::new(p);
        sequential_reference(&app, &spread, &mut expected);

        for (name, result) in [
            ("tdorch", {
                let mut c = Cluster::new(p, CostModel::paper_cluster());
                let mut s: DistStore<u64> = DistStore::new(p);
                TdOrch::new().run_stage(&mut c, &app, spread.clone(), &mut s);
                s.snapshot()
            }),
            ("pull", {
                let mut c = Cluster::new(p, CostModel::paper_cluster());
                let mut s: DistStore<u64> = DistStore::new(p);
                DirectPull.run_stage(&mut c, &app, spread.clone(), &mut s);
                s.snapshot()
            }),
            ("push", {
                let mut c = Cluster::new(p, CostModel::paper_cluster());
                let mut s: DistStore<u64> = DistStore::new(p);
                DirectPush.run_stage(&mut c, &app, spread.clone(), &mut s);
                s.snapshot()
            }),
            ("sort", {
                let mut c = Cluster::new(p, CostModel::paper_cluster());
                let mut s: DistStore<u64> = DistStore::new(p);
                SortingBased.run_stage(&mut c, &app, spread.clone(), &mut s);
                s.snapshot()
            }),
        ] {
            assert_eq!(result, expected.snapshot(), "{name} p={p}");
        }
    }
}

#[test]
fn empty_and_tiny_batches() {
    all_schedulers_match(4, vec![]);
    all_schedulers_match(4, vec![Task::inplace(1, 5)]);
    all_schedulers_match(1, vec![Task::inplace(1, 5), Task::new(1, 2, 3)]);
}

#[test]
fn determinism_same_seed_same_metrics() {
    let mut rng = Rng::new(42);
    let tasks = random_tasks(&mut rng, 800, 120, 0.5, true);
    let run = || {
        let app = CounterApp;
        let mut c = Cluster::new(8, CostModel::paper_cluster());
        let mut s: DistStore<i64> = DistStore::new(8);
        TdOrch::new().run_stage(&mut c, &app, spread_tasks(tasks.clone(), 8), &mut s);
        (
            s.snapshot(),
            c.metrics.total_words,
            c.metrics.supersteps,
            c.metrics.sent_by_machine.clone(),
        )
    };
    assert_eq!(run(), run());
}
