//! Regression suite for the flat shard memory layout: the sparse↔dense
//! frontier switch must be a pure *representation* change.
//!
//! The engine's per-shard frontier starts as a sorted sparse vector and
//! flips to a dense bitset at `seal()` when occupancy crosses
//! 1/`DENSE_OCCUPANCY_DIV` of the owned span (spans under
//! `DENSE_MIN_SPAN` never flip) — both representations iterate in
//! ascending vertex order, so the switch may never change a single bit
//! of any result, on either backend, at any machine count.  This suite
//! pins that:
//!
//! * BFS and CC on a graph big enough that shards cross the threshold
//!   mid-run are bit-identical between the simulator and the threaded
//!   pool at P ∈ {1, 2, 8}, and match sequential references.
//! * A manually-driven BFS observes the flip actually *happening*
//!   (single seed → no dense shards; growth rounds → dense shards) and
//!   still lands exactly on the reference distances — the assertion
//!   would catch a threshold "fix" that silently stopped densifying.
//! * The frontier-entry API pins the mode per seeding shape:
//!   `set_frontier_all` is dense everywhere, a single seed is sparse.
//! * With the flight recorder attached, the deterministic event streams
//!   (per-superstep machine ledgers — work and *words*) are
//!   bit-identical between backends, so the flat layout and the batched
//!   mesh changed no accounted quantity.

mod ref_util;

use ref_util::bfs_ref;
use tdorch::exec::ThreadedCluster;
use tdorch::graph::algorithms::{bfs, cc, BfsShard, CcShard, ShardAccess};
use tdorch::graph::gen;
use tdorch::graph::layout::{DENSE_MIN_SPAN, DENSE_OCCUPANCY_DIV};
use tdorch::graph::spmd::SpmdEngine;
use tdorch::graph::Graph;
use tdorch::obs::FlightRecorder;
use tdorch::{Cluster, CostModel, Substrate};

const PS: [usize; 3] = [1, 2, 8];

fn cost() -> CostModel {
    CostModel::paper_cluster()
}

/// Large enough that every shard at P ≤ 8 has span ≥ `DENSE_MIN_SPAN`
/// and BFS-from-0 pushes shard occupancy past 1/`DENSE_OCCUPANCY_DIV`
/// in the middle rounds (preferential attachment reaches most of the
/// graph within a few hops).
fn switch_graph() -> Graph {
    gen::barabasi_albert(2000, 6, 11)
}

/// Sequential min-label CC reference (exact in f64, so comparisons are
/// plain `==`): iterate label lowering to fixpoint.
fn cc_ref(g: &Graph) -> Vec<u32> {
    let mut label: Vec<u32> = (0..g.n as u32).collect();
    let mut changed = true;
    while changed {
        changed = false;
        for u in 0..g.n as u32 {
            for (v, _) in g.neighbors(u) {
                let m = label[u as usize].min(label[*v as usize]);
                if label[u as usize] != m || label[*v as usize] != m {
                    label[u as usize] = m;
                    label[*v as usize] = m;
                    changed = true;
                }
            }
        }
    }
    label
}

fn run_bfs<B: Substrate>(sub: B, g: &Graph) -> Vec<i64> {
    let mut e = SpmdEngine::tdo_gp(sub, g, cost(), BfsShard::new);
    bfs(&mut e, 0)
}

fn run_cc<B: Substrate>(sub: B, g: &Graph) -> Vec<u32> {
    let mut e = SpmdEngine::tdo_gp(sub, g, cost(), CcShard::new);
    cc(&mut e)
}

#[test]
fn bfs_across_the_switch_is_bitwise_stable_at_every_p() {
    let g = switch_graph();
    let expected = bfs_ref(&g, 0);
    for p in PS {
        let sim = run_bfs(Cluster::new(p, cost()), &g);
        let thr = run_bfs(ThreadedCluster::new(p), &g);
        assert_eq!(sim, expected, "bfs p={p}: simulator != reference");
        assert_eq!(thr, sim, "bfs p={p}: threaded != simulator");
    }
}

#[test]
fn cc_across_the_switch_is_bitwise_stable_at_every_p() {
    let g = switch_graph();
    let expected = cc_ref(&g);
    for p in PS {
        let sim = run_cc(Cluster::new(p, cost()), &g);
        let thr = run_cc(ThreadedCluster::new(p), &g);
        assert_eq!(sim, expected, "cc p={p}: simulator != reference");
        assert_eq!(thr, sim, "cc p={p}: threaded != simulator");
    }
}

#[test]
fn seeding_shape_pins_the_frontier_mode() {
    let g = switch_graph();
    let p = 8;
    let mut e = SpmdEngine::tdo_gp(Cluster::new(p, cost()), &g, cost(), BfsShard::new);

    // Spans at this size comfortably clear the never-densify floor, so
    // the mode below is the occupancy rule speaking, not the span guard.
    assert!(g.n / p >= DENSE_MIN_SPAN);

    // Everything active: full occupancy is trivially ≥ 1/div — every
    // shard must hold the dense bitset.
    e.set_frontier_all();
    assert_eq!(e.frontier_dense_machines(), p, "fill_all must densify every shard");
    assert_eq!(e.frontier_len(), g.n, "fill_all must activate every vertex");

    // One seed: 1/span < 1/div everywhere at this size — no shard may
    // densify, including the seed's owner.
    assert!(DENSE_OCCUPANCY_DIV < g.n / p, "graph too small for the sparse claim");
    e.set_frontier_single(123);
    assert_eq!(e.frontier_dense_machines(), 0, "a single seed must stay sparse");
    assert_eq!(e.frontier_len(), 1);
}

/// Drive BFS round by round (the exact closures `algorithms::bfs` uses)
/// so the test can watch the representation flip mid-run: sparse at the
/// seed, dense once the wave widens, and the final distances still
/// bit-equal to the queue reference.
#[test]
fn bfs_crosses_the_sparse_dense_threshold_mid_run() {
    let g = switch_graph();
    let expected = bfs_ref(&g, 0);
    let mut e = SpmdEngine::tdo_gp(Cluster::new(2, cost()), &g, cost(), BfsShard::new);

    // Seed src=0 by hand: vertex 0 lives at local index 0 of machine 0
    // (ranges are contiguous from 0).
    e.algo_mut(0).shard_mut().dist[0] = 0;
    e.set_frontier_single(0);
    assert_eq!(e.frontier_dense_machines(), 0, "seed round must start sparse");

    let mut seen_dense = false;
    let mut round = 0i64;
    while e.frontier_len() > 0 {
        round += 1;
        assert!(round < 10_000, "BFS failed to terminate");
        let r = round as f64;
        e.edge_map(
            &move |_m, _st: &BfsShard, _u| Some(r),
            &|sv, _u, _v, _w| Some(sv),
            &|a, _b| a,
            &|st: &mut BfsShard, v, val| {
                let st = st.shard_mut();
                let i = (v - st.base) as usize;
                if st.dist[i] < 0 {
                    st.dist[i] = val as i64;
                    true
                } else {
                    false
                }
            },
        );
        seen_dense |= e.frontier_dense_machines() > 0;
    }
    assert!(
        seen_dense,
        "no shard ever densified: the occupancy switch is not engaging on a \
         graph chosen to cross it"
    );
    let got = e.gather(|_m, st| st.shard().dist.clone());
    assert_eq!(got, expected, "mid-run representation flips changed BFS results");
}

#[test]
fn recorder_ledgers_are_bit_identical_across_backends() {
    let g = switch_graph();
    let p = 8;

    let rec_sim = FlightRecorder::shared(tdorch::obs::trace::DEFAULT_CAPACITY);
    let mut es = SpmdEngine::tdo_gp(Cluster::new(p, cost()), &g, cost(), CcShard::new);
    es.set_observer(Some(rec_sim.clone()));
    let sim = cc(&mut es);
    drop(es);

    let rec_thr = FlightRecorder::shared(tdorch::obs::trace::DEFAULT_CAPACITY);
    let mut et = SpmdEngine::tdo_gp(ThreadedCluster::new(p), &g, cost(), CcShard::new);
    et.set_observer(Some(rec_thr.clone()));
    let thr = cc(&mut et);
    drop(et); // joins the pool before the recorder is read

    assert_eq!(thr, sim, "cc p={p}: threaded != simulator");
    let (rs, rt) = (rec_sim.lock().unwrap(), rec_thr.lock().unwrap());
    assert!(!rs.is_empty(), "simulator run recorded no events");
    assert_eq!(
        rs.det_stream(),
        rt.det_stream(),
        "per-superstep machine ledgers diverged: the flat layout or the \
         batched mesh changed an accounted quantity (work/words)"
    );
}
