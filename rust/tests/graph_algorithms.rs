//! Correctness of the five TDO-GP algorithms against single-threaded
//! reference implementations, across machine counts and all four engine
//! families — every family is a Flags configuration of the ONE unified
//! SPMD engine, and all of them must compute identical answers (they
//! differ only in cost structure).

mod common;
mod ref_util;

use ref_util::bfs_ref;
use tdorch::graph::algorithms::{
    bc, bfs, cc, pagerank, sssp, BcShard, BfsShard, CcShard, PrShard, SsspShard,
};
use tdorch::graph::baselines::{gemini_like, la_like, ligra_dist};
use tdorch::graph::spmd::{GraphMeta, SpmdEngine};
use tdorch::graph::{gen, Graph, Vid};
use tdorch::{Cluster, CostModel, MachineId};

// ---------- references (BFS shared via ref_util; SSSP/CC below are
// deliberately different algorithms from the equivalence suite's
// label-correcting oracles — diverse oracles catch more) ----------

fn sssp_ref(g: &Graph, src: Vid) -> Vec<f64> {
    // Dijkstra with a binary heap.
    use std::cmp::Reverse;
    use std::collections::BinaryHeap;
    let mut dist = vec![f64::INFINITY; g.n];
    dist[src as usize] = 0.0;
    let mut heap = BinaryHeap::new();
    heap.push(Reverse((0u64, src)));
    while let Some(Reverse((d_bits, u))) = heap.pop() {
        let d = f64::from_bits(d_bits);
        if d > dist[u as usize] {
            continue;
        }
        for (v, w) in g.neighbors(u) {
            let nd = d + *w as f64;
            if nd < dist[*v as usize] {
                dist[*v as usize] = nd;
                heap.push(Reverse((nd.to_bits(), *v)));
            }
        }
    }
    dist
}

fn cc_ref(g: &Graph) -> Vec<u32> {
    let mut label: Vec<u32> = (0..g.n as u32).collect();
    // Union-find.
    fn find(label: &mut Vec<u32>, v: u32) -> u32 {
        let mut r = v;
        while label[r as usize] != r {
            r = label[r as usize];
        }
        let mut cur = v;
        while label[cur as usize] != r {
            let next = label[cur as usize];
            label[cur as usize] = r;
            cur = next;
        }
        r
    }
    for u in 0..g.n as u32 {
        for (v, _) in g.neighbors(u) {
            let (ru, rv) = (find(&mut label, u), find(&mut label, *v));
            if ru != rv {
                let m = ru.min(rv);
                label[ru as usize] = m;
                label[rv as usize] = m;
            }
        }
    }
    (0..g.n as u32).map(|v| find(&mut label, v)).collect()
}

fn pagerank_ref(g: &Graph, iters: usize) -> Vec<f64> {
    let n = g.n;
    let base = 0.15 / n as f64;
    let mut rank = vec![1.0 / n as f64; n];
    for _ in 0..iters {
        let mut next = vec![base; n];
        for u in 0..n as u32 {
            let d = g.out_degree(u);
            if d == 0 {
                continue;
            }
            let share = 0.85 * rank[u as usize] / d as f64;
            for (v, _) in g.neighbors(u) {
                next[*v as usize] += share;
            }
        }
        rank = next;
    }
    rank
}

fn bc_ref(g: &Graph, root: Vid) -> Vec<f64> {
    // Brandes, single source.
    let n = g.n;
    let mut sigma = vec![0f64; n];
    let mut dist = vec![-1i64; n];
    let mut order = Vec::new();
    sigma[root as usize] = 1.0;
    dist[root as usize] = 0;
    let mut q = std::collections::VecDeque::from([root]);
    while let Some(u) = q.pop_front() {
        order.push(u);
        for (v, _) in g.neighbors(u) {
            let v = *v;
            if dist[v as usize] < 0 {
                dist[v as usize] = dist[u as usize] + 1;
                q.push_back(v);
            }
            if dist[v as usize] == dist[u as usize] + 1 {
                sigma[v as usize] += sigma[u as usize];
            }
        }
    }
    let mut delta = vec![0f64; n];
    for &u in order.iter().rev() {
        for (v, _) in g.neighbors(u) {
            let v = *v;
            if dist[v as usize] == dist[u as usize] + 1 {
                delta[u as usize] +=
                    sigma[u as usize] / sigma[v as usize] * (1.0 + delta[v as usize]);
            }
        }
    }
    delta[root as usize] = 0.0;
    delta
}

// ---------- harness ----------

/// The four engine families, instantiated for one algorithm's shard
/// type: TDO-GP plus the three baseline presets of the same engine.
fn engines<AS: Send>(
    g: &Graph,
    p: usize,
    init: impl Fn(MachineId, &GraphMeta) -> AS + Copy,
) -> Vec<SpmdEngine<Cluster, AS>> {
    let cost = CostModel::paper_cluster();
    vec![
        SpmdEngine::tdo_gp(Cluster::new(p, cost), g, cost, init),
        gemini_like(Cluster::new(p, cost), g, cost, init),
        la_like(Cluster::new(p, cost), g, cost, init),
        ligra_dist(Cluster::new(p, cost), g, cost, init),
    ]
}

fn close(a: f64, b: f64) -> bool {
    (a - b).abs() <= 1e-7 + 1e-6 * a.abs().max(b.abs())
}

#[test]
fn bfs_all_engines_all_p() {
    let g = gen::community_ring(1200, 6, 3, 21);
    let expected = bfs_ref(&g, 0);
    for p in [1, 4, 8] {
        for mut e in engines(&g, p, BfsShard::new) {
            let got = bfs(&mut e, 0);
            assert_eq!(got, expected, "{} p={p}", e.label());
        }
    }
}

#[test]
fn sssp_matches_dijkstra() {
    let g = gen::erdos_renyi(600, 3000, 22);
    let expected = sssp_ref(&g, 5);
    for mut e in engines(&g, 4, SsspShard::new) {
        let got = sssp(&mut e, 5);
        for v in 0..g.n {
            assert!(
                close(got[v], expected[v]) || (got[v].is_infinite() && expected[v].is_infinite()),
                "{} v={v}: {} vs {}",
                e.label(),
                got[v],
                expected[v]
            );
        }
    }
}

#[test]
fn cc_matches_union_find() {
    // A graph with several components: ER below the connectivity
    // threshold plus isolated vertices.
    let g = gen::erdos_renyi(800, 500, 23);
    let expected = cc_ref(&g);
    for mut e in engines(&g, 8, CcShard::new) {
        let got = cc(&mut e);
        assert_eq!(got, expected, "{}", e.label());
    }
}

#[test]
fn pagerank_matches_reference() {
    let g = gen::barabasi_albert(800, 5, 24);
    let expected = pagerank_ref(&g, 8);
    for mut e in engines(&g, 4, PrShard::new) {
        let got = pagerank(&mut e, 8);
        for v in 0..g.n {
            assert!(
                close(got[v], expected[v]),
                "{} v={v}: {} vs {}",
                e.label(),
                got[v],
                expected[v]
            );
        }
        // Ranks are a distribution (up to dangling leakage).
        let sum: f64 = got.iter().sum();
        assert!(sum > 0.5 && sum <= 1.0 + 1e-6, "rank sum {sum}");
    }
}

#[test]
fn bc_matches_brandes() {
    let g = gen::barabasi_albert(500, 4, 25);
    let expected = bc_ref(&g, 3);
    for mut e in engines(&g, 4, BcShard::new) {
        let got = bc(&mut e, 3);
        for v in 0..g.n {
            assert!(
                close(got[v], expected[v]),
                "{} v={v}: {} vs {}",
                e.label(),
                got[v],
                expected[v]
            );
        }
    }
}

#[test]
fn bfs_on_grid_high_diameter() {
    let g = gen::grid2d(24, 26);
    let expected = bfs_ref(&g, 0);
    let cost = CostModel::paper_cluster();
    let mut e = SpmdEngine::tdo_gp(Cluster::new(16, cost), &g, cost, BfsShard::new);
    assert_eq!(bfs(&mut e, 0), expected);
    // Grid diameter from the corner = 2*(side-1) rounds.
    assert_eq!(*expected.iter().max().unwrap(), 46);
}

#[test]
fn disconnected_source_terminates() {
    let mut arcs = vec![(1u32, 2u32, 1.0f32), (2, 1, 1.0)];
    arcs.push((3, 4, 1.0));
    arcs.push((4, 3, 1.0));
    let g = Graph::from_arcs(5, arcs);
    let cost = CostModel::paper_cluster();
    let mut e = SpmdEngine::tdo_gp(Cluster::new(2, cost), &g, cost, BfsShard::new);
    let d = bfs(&mut e, 0); // vertex 0 is isolated
    assert_eq!(d[0], 0);
    assert!(d[1..].iter().all(|x| *x == -1));
}

#[test]
fn tdo_gp_deterministic_across_runs() {
    let g = gen::barabasi_albert(600, 4, 27);
    let run = || {
        let cost = CostModel::paper_cluster();
        let mut e = SpmdEngine::tdo_gp(Cluster::new(8, cost), &g, cost, PrShard::new);
        let r = pagerank(&mut e, 5);
        let m = &e.sub().metrics;
        (r, m.total_words, m.supersteps)
    };
    let (r1, w1, s1) = run();
    let (r2, w2, s2) = run();
    assert_eq!(w1, w2);
    assert_eq!(s1, s2);
    assert_eq!(r1, r2);
}
