//! The serving layer's correctness contract:
//!
//! 1. `reset_for_query` is *observationally* engine reconstruction: a
//!    query on a reset, already-used engine is bit-identical to the same
//!    query on a brand-new engine — this is what licenses `repro serve`
//!    to cross-check against a once-built reference instead of
//!    re-ingesting per query.
//! 2. A threaded server driving a batched mixed stream matches fresh
//!    sim-backend single-shot runs, query by query, bit for bit.
//! 3. A whole serving deployment (serving engine + cross-check engine,
//!    both backends) performs exactly ONE ingestion pass, counted by the
//!    thread-local `graph::ingest::ingestions()` witness.
//! 4. `repro graph` holds the same one-ingestion line after its rewire.

use tdorch::exec::ThreadedCluster;
use tdorch::graph::flags::Flags;
use tdorch::graph::gen;
use tdorch::graph::ingest::ingestions;
use tdorch::graph::spmd::{ingest_once, Placement, SpmdEngine};
use tdorch::graph::Graph;
use tdorch::repro::graphs::run_graph_backend;
use tdorch::serve::{QueryShard, RunOpts, ServeConfig, Server};
use tdorch::workload::{
    generate_stream, hot_source_order, OpenLoopSource, Query, QueryKind, QueryMix, StreamConfig,
};
use tdorch::{Cluster, CostModel};

fn cost() -> CostModel {
    CostModel::paper_cluster()
}

fn cfg() -> ServeConfig {
    ServeConfig {
        batch: 4,
        deadline_ticks: 2,
        queue_cap: 32,
        pr_iters: 3,
        ..ServeConfig::default()
    }
}

fn sim_server(g: &Graph, p: usize) -> Server<Cluster> {
    Server::new(
        SpmdEngine::tdo_gp(Cluster::new(p, cost()), g, cost(), QueryShard::new),
        cfg(),
    )
}

fn q(id: u64, kind: QueryKind, source: u32) -> Query {
    Query { id, kind, source, arrival: 0 }
}

#[test]
fn reset_for_query_matches_fresh_engine_bits() {
    let g = gen::barabasi_albert(600, 5, 11);
    // Probes deliberately differ from the warmup in kind AND source, so
    // any state surviving a reset comes from a *different* query shape.
    let warmup = [
        q(0, QueryKind::Pr, 0),
        q(1, QueryKind::Bfs, 3),
        q(2, QueryKind::Cc, 0),
        q(3, QueryKind::Bc, 9),
        q(4, QueryKind::Sssp, 17),
    ];
    let probes = [
        q(10, QueryKind::Bfs, 0),
        q(11, QueryKind::Sssp, 5),
        q(12, QueryKind::Pr, 0),
        q(13, QueryKind::Cc, 0),
        q(14, QueryKind::Bc, 2),
    ];
    for p in [1usize, 4] {
        let mut served = sim_server(&g, p);
        for w in &warmup {
            served.run_query(w);
        }
        for probe in &probes {
            let reused = served.run_query(probe);
            let fresh = sim_server(&g, p).run_query(probe);
            assert_eq!(
                reused, fresh,
                "p={p} {:?}: reset engine diverged from a fresh engine",
                probe.kind
            );
        }
    }

    // Same property on the threaded backend (the pool outlives queries).
    let mut served = Server::new(
        SpmdEngine::tdo_gp(ThreadedCluster::new(4), &g, cost(), QueryShard::new),
        cfg(),
    );
    for w in &warmup {
        served.run_query(w);
    }
    for probe in &probes {
        let reused = served.run_query(probe);
        let fresh = sim_server(&g, 4).run_query(probe);
        assert_eq!(
            reused, fresh,
            "threaded p=4 {:?}: reset engine diverged from a fresh sim engine",
            probe.kind
        );
    }
}

#[test]
fn threaded_server_stream_matches_fresh_sim_single_shots() {
    let g = gen::barabasi_albert(500, 5, 7);
    let p = 4;
    let dg = ingest_once(&g, p, cost(), Placement::Spread);
    let mut server = Server::new(
        SpmdEngine::from_ingested(
            ThreadedCluster::new(p),
            dg,
            cost(),
            Flags::tdo_gp(),
            "serve-threaded",
            QueryShard::new,
        ),
        cfg(),
    );
    let hot = hot_source_order(&server.engine().meta().out_deg);
    let stream = generate_stream(
        StreamConfig {
            queries: 16,
            per_tick: 4,
            every_ticks: 1,
            zipf_s: 1.5,
            mix: QueryMix::balanced(),
        },
        &hot,
        3,
    );
    let report = server.serve(&mut OpenLoopSource::new(&stream), RunOpts::default());
    assert_eq!(report.served() as u64 + report.rejected, 16);
    assert!(report.served() > 0, "nothing served");
    assert!(report.batches > 0);
    for r in &report.results {
        let query = stream[r.id as usize];
        let fresh = sim_server(&g, p).run_query(&query);
        assert_eq!(
            r.bits, fresh,
            "query {} ({:?}): batched threaded result != fresh sim single-shot",
            r.id, r.kind
        );
    }
    // The pool served the whole stream with P threads and one reset per
    // served query.
    let engine = server.into_engine();
    assert_eq!(engine.sub().pool_threads(), p);
    assert_eq!(engine.resets(), report.served() as u64);
}

#[test]
fn serving_deployment_ingests_exactly_once() {
    let g = gen::barabasi_albert(400, 4, 5);
    let p = 2;
    let before = ingestions();
    let dg = ingest_once(&g, p, cost(), Placement::Spread);
    let mut sim = Server::new(
        SpmdEngine::from_ingested(
            Cluster::new(p, cost()),
            dg.clone(),
            cost(),
            Flags::tdo_gp(),
            "serve-sim",
            QueryShard::new,
        ),
        cfg(),
    );
    let mut thr = Server::new(
        SpmdEngine::from_ingested(
            ThreadedCluster::new(p),
            dg,
            cost(),
            Flags::tdo_gp(),
            "serve-threaded",
            QueryShard::new,
        ),
        cfg(),
    );
    let hot = hot_source_order(&sim.engine().meta().out_deg);
    let stream = generate_stream(
        StreamConfig {
            queries: 24,
            per_tick: 3,
            every_ticks: 1,
            zipf_s: 1.5,
            mix: QueryMix::balanced(),
        },
        &hot,
        9,
    );
    let rep_sim = sim.serve(&mut OpenLoopSource::new(&stream), RunOpts::default());
    let rep_thr = thr.serve(&mut OpenLoopSource::new(&stream), RunOpts::default());
    assert_eq!(
        ingestions() - before,
        1,
        "a serving deployment must ingest once, not per engine or per query"
    );
    // The deterministic batch schedule and every result agree across
    // substrates.
    assert_eq!(rep_sim.served(), rep_thr.served());
    assert_eq!(rep_sim.rejected, rep_thr.rejected);
    assert_eq!(rep_sim.batches, rep_thr.batches);
    assert_eq!(rep_sim.ticks, rep_thr.ticks);
    for (a, b) in rep_sim.results.iter().zip(&rep_thr.results) {
        assert_eq!(a.id, b.id, "dispatch order diverged");
        assert_eq!(a.batch, b.batch, "query {}: batch assignment diverged", a.id);
        assert_eq!(a.wait_ticks, b.wait_ticks, "query {}: wait diverged", a.id);
        assert_eq!(
            a.service_ticks, b.service_ticks,
            "query {}: logical service cost diverged (ledger supersteps must be \
             backend-independent)",
            a.id
        );
        assert_eq!(a.bits, b.bits, "query {}: result bits diverged", a.id);
    }
}

#[test]
fn repro_graph_sim_ingests_once() {
    // The rewired `repro graph` shares one ingestion across everything
    // it runs; its return value folds the counter check in.
    let before = ingestions();
    assert!(run_graph_backend(2, 3, "sim"), "repro graph (sim) reported invalid");
    assert_eq!(ingestions() - before, 1, "repro graph re-ingested the graph");
}

#[test]
fn reset_matches_fresh_engine_bits_across_flag_profiles() {
    // The reset contract is a property of the ENGINE, not of the TDO-GP
    // flag set: a baseline-flagged (or ablated) engine reset between
    // queries stays bit-identical to a fresh engine with the same flags
    // and placement.
    let g = gen::barabasi_albert(500, 5, 13);
    let p = 4;
    let warmup = [
        q(0, QueryKind::Pr, 0),
        q(1, QueryKind::Bc, 3),
        q(2, QueryKind::Sssp, 11),
    ];
    let probes = [
        q(10, QueryKind::Bfs, 0),
        q(11, QueryKind::Sssp, 5),
        q(12, QueryKind::Pr, 0),
        q(13, QueryKind::Cc, 0),
        q(14, QueryKind::Bc, 2),
    ];
    let (t1_label, t1_flags) = Flags::ablations()[0];
    let profiles = [
        ("gemini-like", Flags::gemini_like(), Placement::AtOwner),
        ("la-like", Flags::la_like(), Placement::AtOwner),
        ("ligra-dist", Flags::ligra_dist(), Placement::AtOwner),
        (t1_label, t1_flags, Placement::Spread),
    ];
    for (label, flags, pl) in profiles {
        let build = || {
            Server::new(
                SpmdEngine::new(Cluster::new(p, cost()), &g, cost(), flags, pl, label, QueryShard::new),
                cfg(),
            )
        };
        let mut served = build();
        for w in &warmup {
            served.run_query(w);
        }
        for probe in &probes {
            let reused = served.run_query(probe);
            let fresh = build().run_query(probe);
            assert_eq!(
                reused, fresh,
                "{label} {:?}: reset engine diverged from a fresh engine",
                probe.kind
            );
        }
    }
}
