//! Performance-*shape* tests: the qualitative relationships the paper's
//! evaluation section reports must hold in the simulator (who wins,
//! and roughly how the gaps scale) — Table 2, Table 3, Fig 8/9 shapes —
//! now measured on the unified SPMD engine, the same code the threaded
//! runtime executes (the per-cell ordering matrix lives in
//! `tests/unified_engine_costs.rs`).

mod common;

use tdorch::graph::algorithms::{bc, bfs, pagerank, sssp, BcShard, BfsShard, PrShard, SsspShard};
use tdorch::graph::baselines::{gemini_like, la_like, ligra_dist};
use tdorch::graph::gen;
use tdorch::graph::spmd::SpmdEngine;
use tdorch::{Cluster, CostModel};

fn sim_time<AS: Send>(
    e: &mut SpmdEngine<Cluster, AS>,
    run: impl FnOnce(&mut SpmdEngine<Cluster, AS>),
) -> f64 {
    e.sub_mut().reset_metrics(); // time queries, not ingestion (as the paper does)
    run(e);
    e.sub().metrics.sim_seconds()
}

#[test]
fn high_diameter_graph_blows_up_baselines() {
    // Table 2 Road-USA shape: per-round Θ(n/P) (gemini) or Θ(m/P) (LA)
    // overheads x thousands of rounds vs TDO-GP's work-efficient
    // frontier: the gap must be large (paper: 15x-100x).
    let g = gen::grid2d(340, 31); // n=115k, BFS from the corner takes ~678 rounds
    let p = 8;
    let cost = CostModel::paper_cluster();
    let t_tdo = sim_time(
        &mut SpmdEngine::tdo_gp(Cluster::new(p, cost), &g, cost, BfsShard::new),
        |e| {
            bfs(e, 0);
        },
    );
    let t_gem = sim_time(&mut gemini_like(Cluster::new(p, cost), &g, cost, BfsShard::new), |e| {
        bfs(e, 0);
    });
    let t_la = sim_time(&mut la_like(Cluster::new(p, cost), &g, cost, BfsShard::new), |e| {
        bfs(e, 0);
    });
    assert!(
        t_gem / t_tdo > 2.0,
        "gemini {t_gem:.4}s should be >>x tdo {t_tdo:.4}s"
    );
    assert!(
        t_la / t_tdo > 4.0,
        "la {t_la:.4}s should be >>x tdo {t_tdo:.4}s"
    );
}

#[test]
fn skewed_graph_favors_tdo_gp() {
    // Table 2 social-graph shape: TDO-GP ahead of both families.
    let g = gen::barabasi_albert(60_000, 10, 32);
    let p = 8;
    let cost = CostModel::paper_cluster();
    let t_tdo = sim_time(
        &mut SpmdEngine::tdo_gp(Cluster::new(p, cost), &g, cost, SsspShard::new),
        |e| {
            sssp(e, 0);
        },
    );
    let t_gem = sim_time(&mut gemini_like(Cluster::new(p, cost), &g, cost, SsspShard::new), |e| {
        sssp(e, 0);
    });
    let t_la = sim_time(&mut la_like(Cluster::new(p, cost), &g, cost, SsspShard::new), |e| {
        sssp(e, 0);
    });
    assert!(t_tdo < t_gem, "tdo {t_tdo:.4} !< gemini {t_gem:.4}");
    assert!(t_tdo < t_la, "tdo {t_tdo:.4} !< la {t_la:.4}");
}

#[test]
fn ligra_dist_degrades_with_machines() {
    // Table 3 shape: without TD-Orch, adding machines makes BC *worse*
    // (per-edge contribution messages explode), while TDO-GP improves
    // or stays flat.
    let g = gen::barabasi_albert(20_000, 8, 33);
    let cost = CostModel::paper_cluster();
    let lig_time = |p: usize| {
        sim_time(&mut ligra_dist(Cluster::new(p, cost), &g, cost, BcShard::new), |e| {
            bc(e, 0);
        })
    };
    let lig_1 = lig_time(1);
    let lig_8 = lig_time(8);
    let tdo_8 = sim_time(
        &mut SpmdEngine::tdo_gp(Cluster::new(8, cost), &g, cost, BcShard::new),
        |e| {
            bc(e, 0);
        },
    );
    assert!(
        lig_8 > 2.0 * lig_1,
        "ligra-dist should degrade with machines: P=1 {lig_1:.4} P=8 {lig_8:.4}"
    );
    assert!(
        lig_8 / tdo_8 > 5.0,
        "TD-Orch must be the difference-maker: ligra {lig_8:.4} vs tdo {tdo_8:.4}"
    );
}

#[test]
fn tdo_gp_weak_scaling_near_flat() {
    // Fig 9 shape: fixed edges/machine, runtime ~flat for TDO-GP.
    let cost = CostModel::paper_cluster();
    let mut times = Vec::new();
    for p in [1usize, 2, 4, 8] {
        let g = gen::barabasi_albert(8_000 * p, 8, 34);
        let t = sim_time(
            &mut SpmdEngine::tdo_gp(Cluster::new(p, cost), &g, cost, PrShard::new),
            |e| {
                pagerank(e, 5);
            },
        );
        times.push(t);
    }
    let ratio = times.last().unwrap() / times.first().unwrap();
    assert!(ratio < 3.0, "weak scaling blowup {ratio:.2}: {times:?}");
}

#[test]
fn tdo_gp_strong_scaling_improves() {
    // Fig 8 shape: more machines => faster (near-linear at this scale).
    let g = gen::barabasi_albert(50_000, 12, 35);
    let cost = CostModel::paper_cluster();
    let t1 = sim_time(
        &mut SpmdEngine::tdo_gp(Cluster::new(1, cost), &g, cost, BcShard::new),
        |e| {
            bc(e, 0);
        },
    );
    let t8 = sim_time(
        &mut SpmdEngine::tdo_gp(Cluster::new(8, cost), &g, cost, BcShard::new),
        |e| {
            bc(e, 0);
        },
    );
    assert!(
        t8 < t1 / 2.0,
        "strong scaling: P=8 {t8:.4}s should be well under P=1 {t1:.4}s"
    );
}

#[test]
fn breakdown_reports_all_three_components() {
    // Fig 10 shape: multi-machine runs show nonzero communication,
    // computation AND overhead.
    let g = gen::barabasi_albert(3000, 8, 36);
    let cost = CostModel::paper_cluster();
    let mut e = SpmdEngine::tdo_gp(Cluster::new(8, cost), &g, cost, PrShard::new);
    e.sub_mut().reset_metrics();
    pagerank(&mut e, 5);
    let b = e.sub().metrics.time;
    assert!(b.communication > 0.0);
    assert!(b.computation > 0.0);
    assert!(b.overhead > 0.0);
}

#[test]
fn numa_cost_models_order_pagerank() {
    // Table 5/6 shape: the square-topology NUMA penalty slows local
    // compute; the big all-to-all server is fastest per unit work.
    let g = gen::barabasi_albert(3000, 8, 37);
    let run = |cost: CostModel| {
        let mut e = SpmdEngine::tdo_gp(Cluster::new(1, cost), &g, cost, PrShard::new);
        sim_time(&mut e, |e| {
            pagerank(e, 5);
        })
    };
    let square = run(CostModel::paper_cluster());
    let big = run(CostModel::big_numa_server());
    assert!(big < square, "big server {big:.4} !< paper cluster {square:.4}");
}
