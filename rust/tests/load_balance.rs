//! Load-balance properties (paper Def. 1 / Theorem 1).
//!
//! Under an adversarial workload where *every* task requests the same
//! chunk, TD-Orch must keep per-machine execution and communication
//! balanced (the contexts park on transit machines and the value is
//! pulled down the meta-task tree), while direct-push degenerates to one
//! machine executing everything.

mod common;

use common::CounterApp;
use tdorch::baselines::{DirectPull, DirectPush, SortingBased};
use tdorch::metrics::Metrics;
use tdorch::orchestration::tdorch::TdOrch;
use tdorch::orchestration::{spread_tasks, Scheduler, Task};
use tdorch::{Cluster, CostModel, DistStore};

fn run<S: Scheduler<CounterApp>>(
    sched: &S,
    p: usize,
    tasks: Vec<Task<i64>>,
) -> (Metrics, Vec<u64>) {
    let app = CounterApp;
    let mut cluster = Cluster::new(p, CostModel::paper_cluster());
    let mut store: DistStore<i64> = DistStore::new(p);
    let outcome = sched.run_stage(&mut cluster, &app, spread_tasks(tasks, p), &mut store);
    (cluster.metrics, outcome.executed_per_machine)
}

fn single_key_tasks(n: usize) -> Vec<Task<i64>> {
    (0..n).map(|i| Task::inplace(99, (i % 7) as i64)).collect()
}

#[test]
fn tdorch_balances_execution_under_adversarial_skew() {
    let p = 16;
    let n = 16_000;
    let (_, executed) = run(&TdOrch::new(), p, single_key_tasks(n));
    let imb = Metrics::imbalance(&executed);
    assert!(
        imb < 3.0,
        "TD-Orch execution imbalance {imb:.2} (per-machine: {executed:?})"
    );
    // Every machine executes a meaningful share (Theorem 1(ii)).
    let min = *executed.iter().min().unwrap();
    assert!(min as f64 > 0.2 * (n as f64 / p as f64), "min share {min}");
}

#[test]
fn direct_push_collapses_under_adversarial_skew() {
    let p = 16;
    let n = 16_000;
    let (_, executed) = run(&DirectPush, p, single_key_tasks(n));
    let imb = Metrics::imbalance(&executed);
    assert!(
        imb > 10.0,
        "direct-push should collapse to one machine, imbalance {imb:.2}"
    );
}

#[test]
fn tdorch_communication_balanced_under_skew() {
    let p = 16;
    let (metrics, _) = run(&TdOrch::new(), p, single_key_tasks(16_000));
    let imb = metrics.comm_imbalance();
    assert!(imb < 4.0, "TD-Orch comm imbalance {imb:.2}");
}

#[test]
fn direct_pull_owner_comm_hotspot() {
    // Under single-key load the owner ships P chunk copies while others
    // ship none of comparable size: pull's comm imbalance must exceed
    // TD-Orch's.
    let p = 16;
    let (pull_m, _) = run(&DirectPull, p, single_key_tasks(16_000));
    let (td_m, _) = run(&TdOrch::new(), p, single_key_tasks(16_000));
    assert!(
        pull_m.comm_imbalance() > td_m.comm_imbalance(),
        "pull {:.2} vs td {:.2}",
        pull_m.comm_imbalance(),
        td_m.comm_imbalance()
    );
}

#[test]
fn tdorch_beats_push_and_pull_on_mixed_contention() {
    // The Fig 5 shape: a Zipf-like mix — a mostly-uncontended tail (where
    // pushing σ-word contexts beats pulling B-word chunks, B > σ) plus a
    // few hot keys (where push collapses onto the owners).  TD-Orch's
    // push-pull should beat both directions on simulated time.
    let p = 16;
    let n = 320_000; // ~paper scale ratio: barrier cost amortized
    let tasks: Vec<Task<i64>> = (0..n)
        .map(|i| {
            let addr = if i % 10 < 3 {
                (i % 4) as u64 // 30% on 4 hot keys
            } else {
                100 + (i as u64).wrapping_mul(0x9E3779B9) % 1_000_000
            };
            Task::inplace(addr, (i % 7) as i64)
        })
        .collect();
    let (td, _) = run(&TdOrch::new(), p, tasks.clone());
    let (push, _) = run(&DirectPush, p, tasks.clone());
    let (pull, _) = run(&DirectPull, p, tasks);
    assert!(
        td.sim_seconds() < push.sim_seconds(),
        "td {:.6} !< push {:.6}",
        td.sim_seconds(),
        push.sim_seconds()
    );
    assert!(
        td.sim_seconds() < pull.sim_seconds(),
        "td {:.6} !< pull {:.6}",
        td.sim_seconds(),
        pull.sim_seconds()
    );
}

#[test]
fn sorting_is_balanced_but_talks_more() {
    // §3.6: sorting achieves balance but crosses the network ≥3 times.
    let p = 16;
    let n = 16_000;
    let uniform: Vec<Task<i64>> = (0..n)
        .map(|i| Task::inplace((i as u64 * 2654435761) % 4096, 1))
        .collect();
    let (sort_m, sort_exec) = run(&SortingBased, p, uniform.clone());
    let (td_m, _) = run(&TdOrch::new(), p, uniform);
    assert!(
        Metrics::imbalance(&sort_exec) < 2.0,
        "sorting exec imbalance {:.2}",
        Metrics::imbalance(&sort_exec)
    );
    assert!(
        sort_m.total_words > td_m.total_words,
        "sorting words {} should exceed td-orch {}",
        sort_m.total_words,
        td_m.total_words
    );
}

#[test]
fn uniform_low_contention_all_balanced() {
    // With no contention every scheduler should balance execution.
    let p = 8;
    let n = 8_000;
    let uniform: Vec<Task<i64>> = (0..n)
        .map(|i| Task::inplace((i as u64).wrapping_mul(0x9E3779B9) % 100_000, 1))
        .collect();
    for imb in [
        Metrics::imbalance(&run(&TdOrch::new(), p, uniform.clone()).1),
        Metrics::imbalance(&run(&DirectPull, p, uniform.clone()).1),
        Metrics::imbalance(&run(&DirectPush, p, uniform.clone()).1),
        Metrics::imbalance(&run(&SortingBased, p, uniform.clone()).1),
    ] {
        assert!(imb < 1.5, "imbalance {imb:.2}");
    }
}

#[test]
fn tdorch_weak_scaling_flat() {
    // Theorem 1(i): with n/P fixed, per-stage simulated time grows only
    // polylogarithmically in P. Allow a generous 4x envelope from P=2 to
    // P=16 under heavy skew.
    let per_machine = 2_000;
    let mut times = Vec::new();
    for p in [2usize, 4, 8, 16] {
        let tasks = single_key_tasks(per_machine * p);
        let (m, _) = run(&TdOrch::new(), p, tasks);
        times.push(m.sim_seconds());
    }
    let ratio = times.last().unwrap() / times.first().unwrap();
    assert!(ratio < 4.0, "weak-scaling blowup {ratio:.2}: {times:?}");
}
