//! Integration: the full KV case study (§4) — YCSB workloads through all
//! four schedulers produce identical stores; repeated batches (multi-stage
//! serving) stay consistent; Fig 5 cell shapes hold.

mod common;

use tdorch::baselines::{DirectPull, DirectPush, SortingBased};
use tdorch::kvstore::{preload, Bucket, KvApp, KvOp};
use tdorch::orchestration::tdorch::TdOrch;
use tdorch::orchestration::{sequential_reference, Scheduler, Task};
use tdorch::rng::Rng;
use tdorch::workload::{YcsbKind, YcsbWorkload};
use tdorch::{Cluster, CostModel, DistStore};

const BUCKETS: u64 = 1 << 10;

fn norm(store: &DistStore<Bucket>) -> Vec<(u64, Vec<(u64, u32)>)> {
    store
        .snapshot()
        .into_iter()
        .map(|(a, mut b)| {
            b.sort_by_key(|(k, _)| *k);
            (a, b.into_iter().map(|(k, v)| (k, v.to_bits())).collect())
        })
        .collect()
}

fn make_batches(kind: YcsbKind, p: usize, per: usize, batches: usize) -> Vec<Vec<Vec<Task<KvOp>>>> {
    let w = YcsbWorkload::new(kind, 50_000, 1.8, BUCKETS);
    let mut rng = Rng::new(13);
    let mut seq = 0u64;
    (0..batches)
        .map(|_| {
            (0..p)
                .map(|_| {
                    let b = w.generate(&mut rng, per, seq);
                    seq += per as u64;
                    b
                })
                .collect()
        })
        .collect()
}

fn run_batches<S: Scheduler<KvApp<'static>>>(
    sched: &S,
    p: usize,
    batches: &[Vec<Vec<Task<KvOp>>>],
) -> Vec<(u64, Vec<(u64, u32)>)> {
    let app = KvApp::new(BUCKETS);
    let mut cluster = Cluster::new(p, CostModel::paper_cluster());
    let mut store: DistStore<Bucket> = DistStore::new(p);
    preload(&mut store, BUCKETS, 5_000);
    for batch in batches {
        sched.run_stage(&mut cluster, &app, batch.clone(), &mut store);
    }
    norm(&store)
}

#[test]
fn all_schedulers_agree_on_every_workload() {
    let p = 8;
    for kind in YcsbKind::ALL {
        let batches = make_batches(kind, p, 1_500, 2);

        // Sequential oracle over the same batch sequence.
        let app = KvApp::new(BUCKETS);
        let mut expected: DistStore<Bucket> = DistStore::new(p);
        preload(&mut expected, BUCKETS, 5_000);
        for batch in &batches {
            sequential_reference(&app, batch, &mut expected);
        }
        let expected = norm(&expected);

        assert_eq!(run_batches(&TdOrch::new(), p, &batches), expected, "{kind:?} tdorch");
        assert_eq!(run_batches(&DirectPull, p, &batches), expected, "{kind:?} pull");
        assert_eq!(run_batches(&DirectPush, p, &batches), expected, "{kind:?} push");
        assert_eq!(run_batches(&SortingBased, p, &batches), expected, "{kind:?} sort");
    }
}

#[test]
fn multi_batch_serving_accumulates() {
    // Values written by batch k must be visible to batch k+1 (the store
    // is stateful across orchestration stages).
    let p = 4;
    let app = KvApp::new(BUCKETS);
    let mut cluster = Cluster::new(p, CostModel::paper_cluster());
    let mut store: DistStore<Bucket> = DistStore::new(p);

    let key = 77u64;
    let write = |seq: u64, mul: f32, add: f32| {
        let op = KvOp::update(key, seq, mul, add);
        vec![vec![Task::inplace(op.bucket(BUCKETS), op)], vec![], vec![], vec![]]
    };
    // v = 0*2+3 = 3, then v = 3*10+1 = 31.
    TdOrch::new().run_stage(&mut cluster, &app, write(1, 2.0, 3.0), &mut store);
    TdOrch::new().run_stage(&mut cluster, &app, write(2, 10.0, 1.0), &mut store);
    let op = KvOp::read(key, 3);
    let bucket = store.get(op.bucket(BUCKETS)).unwrap();
    let v = bucket.iter().find(|(k, _)| *k == key).unwrap().1;
    assert_eq!(v, 31.0);
}

#[test]
fn concurrent_writes_resolve_by_sequence() {
    // Many writers to one key in one batch: the highest seq must win on
    // every scheduler (Def. 2(iv) determinism).
    let p = 8;
    let mk = || -> Vec<Vec<Task<KvOp>>> {
        (0..p)
            .map(|m| {
                (0..50)
                    .map(|i| {
                        let seq = (m * 50 + i) as u64;
                        let op = KvOp::update(5, seq, 0.0, seq as f32);
                        Task::inplace(op.bucket(BUCKETS), op)
                    })
                    .collect()
            })
            .collect()
    };
    let winner = (p * 50 - 1) as f32;
    for result in [
        run_batches(&TdOrch::new(), p, &[mk()]),
        run_batches(&DirectPush, p, &[mk()]),
        run_batches(&SortingBased, p, &[mk()]),
    ] {
        let op = KvOp::read(5, 0);
        let bucket = result.iter().find(|(a, _)| *a == op.bucket(BUCKETS)).unwrap();
        let v = bucket.1.iter().find(|(k, _)| *k == 5).unwrap().1;
        assert_eq!(f32::from_bits(v), winner);
    }
}

#[test]
fn fig5_cell_shape_holds_in_ci() {
    use tdorch::repro::kv::run_cell;
    let cell = run_cell(YcsbKind::A, 2.0, 8, 4_000, 3);
    assert!(cell[0] < cell[1], "td {} !< push {}", cell[0], cell[1]);
    assert!(cell[0] < cell[2], "td {} !< pull {}", cell[0], cell[2]);
    assert!(cell[0] < cell[3], "td {} !< sort {}", cell[0], cell[3]);
}

#[test]
fn xla_engine_serving_if_artifacts_present() {
    // Full stack including PJRT, multi-batch.
    let Ok(engine) = tdorch::runtime::Engine::load("artifacts") else {
        eprintln!("SKIP: no artifacts");
        return;
    };
    let p = 4;
    let batches = make_batches(YcsbKind::A, p, 2_000, 2);
    let app = KvApp::with_engine(BUCKETS, &engine);
    let mut cluster = Cluster::new(p, CostModel::paper_cluster());
    let mut store: DistStore<Bucket> = DistStore::new(p);
    preload(&mut store, BUCKETS, 5_000);
    for batch in &batches {
        cluster.barrier();
        TdOrch::new().run_stage(&mut cluster, &app, batch.clone(), &mut store);
    }
    assert_eq!(app.xla_served(), (2 * p * 2_000) as u64);
}
