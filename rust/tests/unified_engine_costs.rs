//! The engine-unification transition contract: every structural
//! cost relation the retired cost-model engine's figure tests asserted
//! must survive on the unified SPMD engine — measured through the exact
//! `repro graphs` figure path (`engines_for` + `run_alg`), so the tests
//! pin what the figures print.
//!
//! 1. Per-algorithm orderings (Table 2 shape): TDO-GP beats gemini-like
//!    and ligra-dist on every algorithm, and beats la-like on every
//!    frontier-sparse algorithm; PR may trade within a small band with
//!    la-like (the paper's own two Table-2 losses are PR cells, blamed
//!    on NUMA-aware linear-algebra local engines).
//! 2. T1–T3 ablation orderings (Table 4 shape): removing any technique
//!    family makes TDO-GP strictly slower, per algorithm.
//! 3. Imbalance bound: on a hub graph whose degree exceeds any machine's
//!    fair share, TDO-GP's transit-machine blocks beat owner placement's
//!    work imbalance on a full-frontier round.

use tdorch::graph::algorithms::Algorithm;
use tdorch::graph::flags::Flags;
use tdorch::graph::gen;
use tdorch::graph::spmd::{ingest_once, Placement, SpmdEngine};
use tdorch::graph::Graph;
use tdorch::repro::graphs::{engines_for, ordering_violations, run_alg};
use tdorch::serve::QueryShard;
use tdorch::{Cluster, CostModel};

fn cost() -> CostModel {
    CostModel::paper_cluster()
}

#[test]
fn tdo_gp_orders_below_baselines_per_algorithm() {
    let g = gen::barabasi_albert(4_000, 8, 17);
    let p = 8;
    let mut engines = engines_for(&g, p, cost());
    for alg in Algorithm::ALL {
        let secs: Vec<f64> = engines.iter_mut().map(|e| run_alg(e, alg).0).collect();
        // The claims live in ONE place (`repro::graphs::ordering_violations`)
        // so this test and the `repro graphs --quick` CI smoke can never
        // disagree about the same structural relation.
        let violations = ordering_violations(alg, &secs);
        assert!(violations.is_empty(), "{}", violations.join("; "));
    }
}

#[test]
fn technique_ablations_cost_more_per_algorithm() {
    let g = gen::barabasi_albert(2_000, 6, 17);
    let p = 8;
    // One spread placement; full and ablated engines differ in flags only.
    let dg = ingest_once(&g, p, cost(), Placement::Spread);
    let run = |flags: Flags, label: &str, alg: Algorithm| {
        run_alg(
            &mut SpmdEngine::from_ingested(
                Cluster::new(p, cost()),
                dg.clone(),
                cost(),
                flags,
                label,
                QueryShard::new,
            ),
            alg,
        )
        .0
    };
    for alg in [Algorithm::Bfs, Algorithm::Sssp, Algorithm::Cc, Algorithm::Bc] {
        let full = run(Flags::tdo_gp(), "tdo-gp", alg);
        for (label, flags) in Flags::ablations() {
            let ablated = run(flags, label, alg);
            assert!(
                ablated > full,
                "{}: {label} {ablated:.5} !> full {full:.5}",
                alg.label()
            );
        }
    }
}

#[test]
fn tdo_balances_hub_work_vs_owner_placement() {
    // A hub whose degree exceeds m/P cannot be balanced by vertex
    // partitioning alone: TDO-GP's transit-machine blocks must beat
    // owner placement on a full-frontier round.
    let mut arcs = Vec::new();
    for v in 1..3000u32 {
        arcs.push((0, v, 1.0));
        arcs.push((v, 0, 1.0));
        let w = if v == 2999 { 1 } else { v + 1 };
        arcs.push((v, w, 1.0));
        arcs.push((w, v, 1.0));
    }
    let g = Graph::from_arcs(3000, arcs);
    let run = |flags: Flags, pl: Placement, label: &str| {
        let mut engine =
            SpmdEngine::new(Cluster::new(8, cost()), &g, cost(), flags, pl, label, |_m, _meta| ());
        engine.set_frontier_all();
        engine.sub_mut().reset_metrics();
        engine.edge_map(
            &|_m, _st, _u| Some(1.0),
            &|sv, _u, _v, _w| Some(sv),
            &|a, b| a + b,
            &|_st, _v, _val| false,
        );
        engine.sub().metrics.work_imbalance()
    };
    let tdo = run(Flags::tdo_gp(), Placement::Spread, "tdo-gp");
    let gem = run(Flags::gemini_like(), Placement::AtOwner, "gemini-like");
    assert!(
        tdo < gem,
        "tdo imbalance {tdo:.2} should beat owner placement {gem:.2}"
    );
}

#[test]
fn per_edge_wire_shape_is_the_expensive_one() {
    // The ligra-dist prototype's only wire difference from a premerged
    // direct engine is per-edge RPC contributions; at P>1 that must
    // dominate its round cost (Table 3's "no TD-Orch" cliff).  Same
    // placement, same work multiplier — flags isolate the wire shape.
    let g = gen::barabasi_albert(3_000, 8, 29);
    let mut premerged = Flags::ligra_dist();
    premerged.premerge = true;
    let run = |flags: Flags, label: &str| {
        run_alg(
            &mut SpmdEngine::baseline(
                Cluster::new(8, cost()),
                &g,
                cost(),
                flags,
                label,
                QueryShard::new,
            ),
            Algorithm::Bfs,
        )
        .0
    };
    let per_edge = run(Flags::ligra_dist(), "per-edge");
    let merged = run(premerged, "premerged");
    assert!(
        per_edge > 2.0 * merged,
        "per-edge RPC {per_edge:.5} should dwarf premerged {merged:.5}"
    );
}
