//! Cross-backend equivalence for the unified SPMD engine: for each
//! algorithm in {PageRank, BFS, SSSP, CC, BC} × engine flags in
//! {TDO-GP, direct/gemini-like, per-edge/ligra-dist} × P ∈ {1, 2, 8},
//! the *threaded* backend (persistent worker pool, real channels) must
//! be **bit-identical** to the BSP *simulator*, and both must match a
//! single-machine reference (mirrors `tests/exec_equivalence.rs`).
//!
//! The reference comparison has two strengths, per the determinism
//! contract in `src/graph/spmd.rs`:
//!
//! * BFS, SSSP, CC merge with `min`/first-writer — exact in f64 — so
//!   every (flags, P) cell is bit-identical to the sequential reference.
//! * PageRank merges with `+`, which rounds, so the fold *grouping* is
//!   part of the bits: P=1 is bit-identical to a reference folding
//!   in-edge contributions in ascending source order (that is the P=1
//!   block-scan order); P>1 regroups the same sums per shard/tree and
//!   must match the reference to 1e-9 relative — while remaining
//!   bit-identical *across backends*, which is the claim under test.
//! * BC also merges with `+` (σ and dependency shares), and its Brandes
//!   reference accumulates in BFS-queue order rather than block order,
//!   so every (flags, P) cell is rounding-close to the reference and
//!   bit-identical across backends.
//!
//! Also here: the determinism property for oversubscribed pools (two
//! threaded runs at P=16 — more workers than CI cores — produce
//! identical ledgers and bits) and the persistent-pool regression
//! (exactly one barrier epoch per superstep, at most P threads ever).

mod ref_util;

use ref_util::bfs_ref;
use tdorch::exec::ThreadedCluster;
use tdorch::graph::algorithms::{
    bc, bfs, cc, pagerank, sssp, BcShard, BfsShard, CcShard, PrShard, SsspShard, DAMPING,
};
use tdorch::graph::flags::Flags;
use tdorch::graph::gen;
use tdorch::graph::spmd::{Placement, SpmdEngine};
use tdorch::graph::{Graph, Vid};
use tdorch::{Cluster, CostModel, Substrate};

const PS: [usize; 3] = [1, 2, 8];
const PR_ITERS: usize = 5;

fn cost() -> CostModel {
    CostModel::paper_cluster()
}

/// The engine variants under test: TDO-GP and the two "direct" baseline
/// shapes (pre-merged direct fan-in, and per-edge messages).
fn variants() -> [(&'static str, Flags, Placement); 3] {
    [
        ("tdo-gp", Flags::tdo_gp(), Placement::Spread),
        ("direct", Flags::gemini_like(), Placement::AtOwner),
        ("per-edge", Flags::ligra_dist(), Placement::AtOwner),
    ]
}

// ---- sequential references (BFS is shared via `ref_util`; SSSP/CC/PR
// are deliberately *different* algorithms from `graph_algorithms.rs`'s
// Dijkstra/union-find oracles — diverse oracles, and f64 evaluation
// order here is part of the bit-exactness argument) ----

/// Label-correcting SSSP.  The final value per vertex is the `min` over
/// all path sums (each computed source-to-vertex left to right), which is
/// evaluation-order independent — hence bit-comparable to the engines.
fn sssp_ref(g: &Graph, src: Vid) -> Vec<f64> {
    let mut dist = vec![f64::INFINITY; g.n];
    dist[src as usize] = 0.0;
    let mut changed = true;
    while changed {
        changed = false;
        for u in 0..g.n as Vid {
            if !dist[u as usize].is_finite() {
                continue;
            }
            for (v, w) in g.neighbors(u) {
                let cand = dist[u as usize] + *w as f64;
                if cand < dist[*v as usize] {
                    dist[*v as usize] = cand;
                    changed = true;
                }
            }
        }
    }
    dist
}

fn cc_ref(g: &Graph) -> Vec<u32> {
    let mut label: Vec<u32> = (0..g.n as u32).collect();
    let mut changed = true;
    while changed {
        changed = false;
        for u in 0..g.n as Vid {
            for (v, _) in g.neighbors(u) {
                let l = label[u as usize];
                if l < label[*v as usize] {
                    label[*v as usize] = l;
                    changed = true;
                }
            }
        }
    }
    label
}

/// PageRank folding each vertex's in-contributions in ascending source
/// order — the exact order a P=1 block scan produces.
fn pr_ref(g: &Graph, iters: usize) -> Vec<f64> {
    let n = g.n;
    let base = (1.0 - DAMPING) / n as f64;
    let mut rank = vec![1.0 / n as f64; n];
    for _ in 0..iters {
        let mut agg: Vec<Option<f64>> = vec![None; n];
        for u in 0..n as Vid {
            let d = g.out_degree(u);
            if d == 0 {
                continue;
            }
            let share = rank[u as usize] / d as f64;
            for (v, _) in g.neighbors(u) {
                let slot = &mut agg[*v as usize];
                *slot = Some(match *slot {
                    Some(a) => a + share,
                    None => share,
                });
            }
        }
        rank = agg
            .into_iter()
            .map(|a| match a {
                Some(a) => base + DAMPING * a,
                None => base,
            })
            .collect();
    }
    rank
}

/// Brandes BC, single source — accumulation order is BFS-queue order,
/// different from any block scan, so the comparison is rounding-close
/// (the cross-backend comparison stays bitwise).
fn bc_ref(g: &Graph, root: Vid) -> Vec<f64> {
    let n = g.n;
    let mut sigma = vec![0f64; n];
    let mut dist = vec![-1i64; n];
    let mut order = Vec::new();
    sigma[root as usize] = 1.0;
    dist[root as usize] = 0;
    let mut q = std::collections::VecDeque::from([root]);
    while let Some(u) = q.pop_front() {
        order.push(u);
        for (v, _) in g.neighbors(u) {
            let v = *v;
            if dist[v as usize] < 0 {
                dist[v as usize] = dist[u as usize] + 1;
                q.push_back(v);
            }
            if dist[v as usize] == dist[u as usize] + 1 {
                sigma[v as usize] += sigma[u as usize];
            }
        }
    }
    let mut delta = vec![0f64; n];
    for &u in order.iter().rev() {
        for (v, _) in g.neighbors(u) {
            let v = *v;
            if dist[v as usize] == dist[u as usize] + 1 {
                delta[u as usize] +=
                    sigma[u as usize] / sigma[v as usize] * (1.0 + delta[v as usize]);
            }
        }
    }
    delta[root as usize] = 0.0;
    delta
}

// ---- engine runners, generic over the substrate ----

fn run_bfs<B: Substrate>(sub: B, g: &Graph, flags: Flags, pl: Placement) -> Vec<i64> {
    let mut e = SpmdEngine::new(sub, g, cost(), flags, pl, "bfs", BfsShard::new);
    bfs(&mut e, 0)
}

fn run_sssp<B: Substrate>(sub: B, g: &Graph, flags: Flags, pl: Placement) -> Vec<f64> {
    let mut e = SpmdEngine::new(sub, g, cost(), flags, pl, "sssp", SsspShard::new);
    sssp(&mut e, 0)
}

fn run_cc<B: Substrate>(sub: B, g: &Graph, flags: Flags, pl: Placement) -> Vec<u32> {
    let mut e = SpmdEngine::new(sub, g, cost(), flags, pl, "cc", CcShard::new);
    cc(&mut e)
}

fn run_pr<B: Substrate>(sub: B, g: &Graph, flags: Flags, pl: Placement) -> Vec<f64> {
    let mut e = SpmdEngine::new(sub, g, cost(), flags, pl, "pr", PrShard::new);
    pagerank(&mut e, PR_ITERS)
}

fn run_bc<B: Substrate>(sub: B, g: &Graph, flags: Flags, pl: Placement) -> Vec<f64> {
    let mut e = SpmdEngine::new(sub, g, cost(), flags, pl, "bc", BcShard::new);
    bc(&mut e, 0)
}

fn assert_bits_eq(a: &[f64], b: &[f64], msg: &str) {
    assert_eq!(a.len(), b.len(), "{msg}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{msg}: vertex {i}: {x} vs {y}");
    }
}

fn assert_close(a: &[f64], b: &[f64], rel: f64, msg: &str) {
    assert_eq!(a.len(), b.len(), "{msg}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        let scale = x.abs().max(y.abs()).max(1e-30);
        assert!(
            (x - y).abs() / scale < rel,
            "{msg}: vertex {i}: {x} vs {y} (rel {})",
            (x - y).abs() / scale
        );
    }
}

#[test]
fn bfs_threaded_bitwise_equals_simulator_and_reference() {
    let g = gen::barabasi_albert(700, 5, 42);
    let expected = bfs_ref(&g, 0);
    for (label, flags, pl) in variants() {
        for p in PS {
            let sim = run_bfs(Cluster::new(p, cost()), &g, flags, pl);
            let thr = run_bfs(ThreadedCluster::new(p), &g, flags, pl);
            assert_eq!(sim, expected, "bfs/{label} p={p}: simulator != reference");
            assert_eq!(thr, sim, "bfs/{label} p={p}: threaded != simulator");
        }
    }
}

#[test]
fn sssp_threaded_bitwise_equals_simulator_and_reference() {
    let g = gen::barabasi_albert(700, 5, 42);
    let expected = sssp_ref(&g, 0);
    for (label, flags, pl) in variants() {
        for p in PS {
            let sim = run_sssp(Cluster::new(p, cost()), &g, flags, pl);
            let thr = run_sssp(ThreadedCluster::new(p), &g, flags, pl);
            assert_bits_eq(&sim, &expected, &format!("sssp/{label} p={p} sim vs ref"));
            assert_bits_eq(&thr, &sim, &format!("sssp/{label} p={p} thr vs sim"));
        }
    }
}

#[test]
fn cc_threaded_bitwise_equals_simulator_and_reference() {
    // community_ring has several dense clusters bridged sparsely — a
    // harder label-propagation workload than one giant component.
    let g = gen::community_ring(600, 6, 8, 42);
    let expected = cc_ref(&g);
    for (label, flags, pl) in variants() {
        for p in PS {
            let sim = run_cc(Cluster::new(p, cost()), &g, flags, pl);
            let thr = run_cc(ThreadedCluster::new(p), &g, flags, pl);
            assert_eq!(sim, expected, "cc/{label} p={p}: simulator != reference");
            assert_eq!(thr, sim, "cc/{label} p={p}: threaded != simulator");
        }
    }
}

#[test]
fn pagerank_threaded_bitwise_equals_simulator() {
    let g = gen::barabasi_albert(700, 5, 42);
    let expected = pr_ref(&g, PR_ITERS);
    for (label, flags, pl) in variants() {
        for p in PS {
            let sim = run_pr(Cluster::new(p, cost()), &g, flags, pl);
            let thr = run_pr(ThreadedCluster::new(p), &g, flags, pl);
            // The headline claim: real threads == simulator, bit for bit.
            assert_bits_eq(&thr, &sim, &format!("pr/{label} p={p} thr vs sim"));
            if p == 1 {
                // P=1 block order IS ascending-source order: exact.
                assert_bits_eq(&sim, &expected, &format!("pr/{label} p=1 sim vs ref"));
            } else {
                // P>1 regroups the same f64 sums: rounding-close only.
                assert_close(&sim, &expected, 1e-9, &format!("pr/{label} p={p} sim vs ref"));
            }
        }
    }
}

#[test]
fn bc_threaded_bitwise_equals_simulator() {
    let g = gen::barabasi_albert(700, 5, 42);
    let expected = bc_ref(&g, 0);
    for (label, flags, pl) in variants() {
        for p in PS {
            let sim = run_bc(Cluster::new(p, cost()), &g, flags, pl);
            let thr = run_bc(ThreadedCluster::new(p), &g, flags, pl);
            // The headline claim: real threads == simulator, bit for bit.
            assert_bits_eq(&thr, &sim, &format!("bc/{label} p={p} thr vs sim"));
            // σ/δ regroup per shard/tree vs the queue-order reference:
            // rounding-close at every (flags, P).
            assert_close(&sim, &expected, 1e-9, &format!("bc/{label} p={p} sim vs ref"));
        }
    }
}

#[test]
fn ablated_flag_profiles_do_not_change_results() {
    // Correctness is flag-independent: the T1/T2/T3 ablation engines
    // (and their threaded twins) compute bit-identical SSSP answers —
    // the knobs may only move cost, never results.
    let g = gen::barabasi_albert(900, 5, 7);
    let expected = sssp_ref(&g, 0);
    for (label, flags) in Flags::ablations() {
        let sim = run_sssp(Cluster::new(8, cost()), &g, flags, Placement::Spread);
        let thr = run_sssp(ThreadedCluster::new(8), &g, flags, Placement::Spread);
        assert_bits_eq(&sim, &expected, &format!("sssp/{label} sim vs ref"));
        assert_bits_eq(&thr, &sim, &format!("sssp/{label} thr vs sim"));
    }
}

#[test]
fn oversubscribed_threaded_runs_are_deterministic() {
    // P=16 workers on a small CI box is heavily oversubscribed; the
    // schedule varies wildly between runs, but the results AND the whole
    // accounting ledger (work, bytes, messages, supersteps, per-machine
    // orderings) must not.
    let g = gen::barabasi_albert(500, 5, 9);
    let run = || {
        let mut e = SpmdEngine::tdo_gp(ThreadedCluster::new(16), &g, cost(), PrShard::new);
        let rank = pagerank(&mut e, PR_ITERS);
        // (clone: ThreadedCluster has a Drop impl that joins the pool)
        let ledger = e.sub().metrics.clone();
        (rank, ledger)
    };
    let (rank_a, m_a) = run();
    let (rank_b, m_b) = run();
    assert_bits_eq(&rank_a, &rank_b, "oversubscribed rank bits");
    assert_eq!(m_a.work_by_machine, m_b.work_by_machine, "work ledger");
    assert_eq!(m_a.sent_by_machine, m_b.sent_by_machine, "sent-bytes ledger");
    assert_eq!(m_a.recv_by_machine, m_b.recv_by_machine, "recv-bytes ledger");
    assert_eq!(m_a.total_words, m_b.total_words, "total words");
    assert_eq!(m_a.total_msgs, m_b.total_msgs, "total msgs");
    assert_eq!(m_a.supersteps, m_b.supersteps, "superstep count");

    // Same seed ⇒ same ledger also vs the single-threaded simulator run
    // of the identical engine (the substrate must not leak into the
    // accounting).
    let mut sim = SpmdEngine::tdo_gp(Cluster::new(16, cost()), &g, cost(), PrShard::new);
    let rank_sim = pagerank(&mut sim, PR_ITERS);
    assert_bits_eq(&rank_a, &rank_sim, "threaded vs simulator bits");
    let cm = &sim.sub().metrics;
    assert_eq!(m_a.work_by_machine, cm.work_by_machine, "work ledger vs simulator");
}

#[test]
fn persistent_pool_one_epoch_per_superstep() {
    // The pool must execute exactly one barrier epoch per superstep on
    // every worker — no lost or duplicated payload rounds — and never
    // spawn more than P threads however many supersteps run.
    let g = gen::barabasi_albert(400, 4, 3);
    let p = 4;
    let mut e = SpmdEngine::tdo_gp(ThreadedCluster::new(p), &g, cost(), SsspShard::new);
    let dist = sssp(&mut e, 0);
    assert!(dist.iter().filter(|d| d.is_finite()).count() > 1, "sssp reached nothing");
    let tc = e.into_sub();
    assert_eq!(tc.pool_threads(), p, "pool grew beyond P threads");
    let epochs = tc.epochs();
    assert!(epochs > 0, "no epochs recorded");
    assert_eq!(
        tc.worker_epochs(),
        vec![epochs; p],
        "workers disagree on epoch count: a superstep was lost or duplicated"
    );
    // Every *accounted* superstep is an epoch (ledger-empty barriers are
    // epochs too, so epochs ≥ supersteps).
    assert!(
        epochs >= tc.metrics.supersteps,
        "fewer epochs ({epochs}) than accounted supersteps ({})",
        tc.metrics.supersteps
    );
}

#[test]
fn threaded_spawn_failure_is_loud() {
    // An impossible worker stack cannot be mapped: the constructor must
    // fail closed (error, not a smaller pool and not a hang).
    let err = ThreadedCluster::try_new_with_stack(8, Some(usize::MAX / 2));
    match err {
        Err(e) => {
            let msg = e.to_string();
            assert!(msg.contains("of 8 worker threads"), "missing context: {msg}");
        }
        Ok(tc) => panic!(
            "spawning with an impossible stack unexpectedly succeeded ({} threads)",
            tc.pool_threads()
        ),
    }
}
