//! The live-mutation correctness contract:
//!
//! 1. The seeded `MutationStream` is a pure function of (config, graph,
//!    hotness order, seed) — bit-identical across machine counts and
//!    backends, like the query stream.
//! 2. `SpmdEngine::apply_delta` keeps the engine's catalog (degrees,
//!    arc count, leaf sets, relay trees) exactly in sync with replaying
//!    the same batches onto the `DistGraph` by `apply_batch`, and every
//!    relay tree — dirty or not — equals a from-scratch
//!    `relay_tree_levels` computation on the mutated leaf sets.
//! 3. Queries on a delta-mutated engine are bit-identical to a fresh
//!    engine built from the replayed placement — for ALL five kinds,
//!    because in-place deltas preserve block layout and hence f64 fold
//!    grouping.
//! 4. A full interleaved mutating serve run is bit-identical across the
//!    sim and threaded substrates: epochs, waits, mutation records,
//!    result bits — and the deployment still ingests exactly once.
//! 5. For the exact (min/first-writer) kinds, the mutated engine also
//!    matches a TRUE fresh ingestion of the mutated edge set — the
//!    placement-independent end of the determinism contract.

use tdorch::det::det_map;
use tdorch::exec::ThreadedCluster;
use tdorch::graph::flags::Flags;
use tdorch::graph::gen;
use tdorch::graph::ingest::{ingestions, relay_tree_levels, DistGraph};
use tdorch::graph::spmd::{ingest_once, Placement, SpmdEngine};
use tdorch::graph::{Graph, Vid};
use tdorch::mutate::{
    generate_mutations, recompute_leaves, EdgeOp, MutationConfig, MutationFeed, MutationStream,
};
use tdorch::serve::{QueryShard, RunOpts, ServeConfig, Server};
use tdorch::workload::{
    generate_stream, hot_source_order, OpenLoopSource, Query, QueryKind, QueryMix, StreamConfig,
};
use tdorch::{Cluster, CostModel};

fn cost() -> CostModel {
    CostModel::paper_cluster()
}

fn cfg() -> ServeConfig {
    ServeConfig {
        batch: 4,
        deadline_ticks: 2,
        queue_cap: 32,
        pr_iters: 3,
        ..ServeConfig::default()
    }
}

fn mcfg(batches: usize) -> MutationConfig {
    MutationConfig {
        batches,
        ops_per_batch: 6,
        insert_pct: 60,
        zipf_s: 1.2,
        start_tick: 1,
        every_ticks: 3,
    }
}

fn batches_for(g: &Graph, n_batches: usize, seed: u64) -> MutationStream {
    let hot_deg: Vec<u32> = (0..g.n as Vid).map(|u| g.out_degree(u) as u32).collect();
    let hot = hot_source_order(&hot_deg);
    generate_mutations(mcfg(n_batches), g, &hot, seed)
}

#[test]
fn mutation_stream_is_machine_count_independent() {
    let g = gen::barabasi_albert(500, 5, 11);
    // The hotness order the stream is addressed by comes from the
    // GLOBAL degree vector, which every placement at every P carries
    // identically — so the stream is a pure function of the graph, not
    // of the deployment.
    let streams: Vec<MutationStream> = [1usize, 8]
        .iter()
        .map(|&p| {
            let dg = ingest_once(&g, p, cost(), Placement::Spread);
            let engine = SpmdEngine::from_ingested(
                Cluster::new(p, cost()),
                dg,
                cost(),
                Flags::tdo_gp(),
                "stream-p",
                QueryShard::new,
            );
            let hot = hot_source_order(&engine.meta().out_deg);
            generate_mutations(mcfg(4), &g, &hot, 23)
        })
        .collect();
    assert_eq!(streams[0], streams[1], "stream depends on P");
    // Threaded deployments see the same meta, hence the same stream.
    let thr = SpmdEngine::from_ingested(
        ThreadedCluster::new(8),
        ingest_once(&g, 8, cost(), Placement::Spread),
        cost(),
        Flags::tdo_gp(),
        "stream-thr",
        QueryShard::new,
    );
    let hot = hot_source_order(&thr.meta().out_deg);
    assert_eq!(
        streams[0],
        generate_mutations(mcfg(4), &g, &hot, 23),
        "stream depends on the backend"
    );
}

#[test]
fn apply_delta_keeps_catalog_in_sync_with_replay_and_fresh_trees() {
    let g = gen::barabasi_albert(500, 5, 7);
    let p = 4;
    let before = ingestions();
    let dg = ingest_once(&g, p, cost(), Placement::Spread);
    let mut replay: DistGraph = dg.clone();
    let mut engine = SpmdEngine::from_ingested(
        Cluster::new(p, cost()),
        dg,
        cost(),
        Flags::tdo_gp(),
        "delta-sync",
        QueryShard::new,
    );
    let batches = batches_for(&g, 3, 17);
    for (i, b) in batches.iter().enumerate() {
        let applied_engine = engine.apply_delta(b);
        let applied_replay = replay.apply_batch(b);
        assert_eq!(applied_engine, applied_replay, "batch {i}: applied counts diverged");
        assert_eq!(engine.graph_epoch(), i as u64 + 1);
    }
    assert_eq!(
        ingestions() - before,
        1,
        "apply_delta must patch in place, never re-ingest"
    );

    let meta = engine.meta();
    assert_eq!(meta.m, replay.m, "arc count diverged");
    assert_eq!(meta.out_deg, replay.out_deg, "degree vector diverged");
    assert_eq!(meta.src_leaves, replay.src_leaves, "src leaves diverged");
    assert_eq!(meta.dst_leaves, replay.dst_leaves, "dst leaves diverged");
    // Leaf sets must also match the ground truth recomputed from the
    // replayed blocks (catches leaves drifting from block contents).
    let (src_truth, dst_truth) = recompute_leaves(&replay);
    assert_eq!(meta.src_leaves, src_truth, "src leaves != block ground truth");
    assert_eq!(meta.dst_leaves, dst_truth, "dst leaves != block ground truth");

    // Every relay tree — rebuilt-dirty or untouched — equals the
    // from-scratch computation on the mutated leaf sets, with the
    // construction-time keys.
    for u in 0..meta.n {
        assert_eq!(
            meta.src_tree[u],
            relay_tree_levels(u as u64, &meta.src_leaves[u], meta.part.owner(u as Vid), meta.c, p),
            "src tree of {u} != from-scratch tree on the mutated graph"
        );
        assert_eq!(
            meta.dst_tree[u],
            relay_tree_levels(
                u as u64 ^ 0xD5,
                &meta.dst_leaves[u],
                meta.part.owner(u as Vid),
                meta.c,
                p
            ),
            "dst tree of {u} != from-scratch tree on the mutated graph"
        );
    }
}

#[test]
fn queries_after_delta_match_fresh_engine_on_replayed_placement() {
    let g = gen::barabasi_albert(500, 5, 13);
    let p = 4;
    let dg = ingest_once(&g, p, cost(), Placement::Spread);
    let mut replay = dg.clone();
    let mut engine = SpmdEngine::from_ingested(
        Cluster::new(p, cost()),
        dg,
        cost(),
        Flags::tdo_gp(),
        "delta-query",
        QueryShard::new,
    );
    for b in &batches_for(&g, 3, 29) {
        engine.apply_delta(b);
        replay.apply_batch(b);
    }
    // In-place deltas preserve block layout, so the replayed placement
    // is bit-exact for every kind — including the f64-fold ones.
    let mut mutated = Server::new(engine, cfg());
    let mut reference = Server::new(
        SpmdEngine::from_ingested(
            Cluster::new(p, cost()),
            replay,
            cost(),
            Flags::tdo_gp(),
            "delta-query-ref",
            QueryShard::new,
        ),
        cfg(),
    );
    for (id, kind) in QueryKind::ALL.into_iter().enumerate() {
        let q = Query { id: id as u64, kind, source: 0, arrival: 0 };
        assert_eq!(
            mutated.run_query(&q),
            reference.run_query(&q),
            "{kind:?}: mutated engine != fresh engine on the replayed placement"
        );
    }
}

#[test]
fn mutating_serve_is_bit_identical_across_backends() {
    let g = gen::barabasi_albert(600, 5, 3);
    let p = 8;
    let before = ingestions();
    let dg = ingest_once(&g, p, cost(), Placement::Spread);
    let hot_deg: Vec<u32> = (0..g.n as Vid).map(|u| g.out_degree(u) as u32).collect();
    let hot = hot_source_order(&hot_deg);
    let stream = generate_stream(
        StreamConfig { queries: 12, per_tick: 2, every_ticks: 1, zipf_s: 1.5, mix: QueryMix::balanced() },
        &hot,
        5,
    );
    let batches = generate_mutations(mcfg(3), &g, &hot, 31);

    let mut sim = Server::new(
        SpmdEngine::from_ingested(
            Cluster::new(p, cost()),
            dg.clone(),
            cost(),
            Flags::tdo_gp(),
            "mutate-sim",
            QueryShard::new,
        ),
        cfg(),
    );
    let mut sim_feed = MutationFeed::new(batches.clone());
    let rep_sim =
        sim.serve(&mut OpenLoopSource::new(&stream), RunOpts::new().feed(&mut sim_feed));
    let mut thr = Server::new(
        SpmdEngine::from_ingested(
            ThreadedCluster::new(p),
            dg,
            cost(),
            Flags::tdo_gp(),
            "mutate-thr",
            QueryShard::new,
        ),
        cfg(),
    );
    let mut thr_feed = MutationFeed::new(batches.clone());
    let rep_thr =
        thr.serve(&mut OpenLoopSource::new(&stream), RunOpts::new().feed(&mut thr_feed));
    assert_eq!(
        ingestions() - before,
        1,
        "a mutating deployment on both backends still ingests exactly once"
    );

    assert_eq!(rep_sim.served(), rep_thr.served());
    assert_eq!(rep_sim.rejected, rep_thr.rejected);
    assert_eq!(rep_sim.batches, rep_thr.batches);
    assert_eq!(rep_sim.ticks, rep_thr.ticks);
    assert_eq!(rep_sim.graph_epoch, rep_thr.graph_epoch, "final epoch diverged");
    assert_eq!(
        rep_sim.graph_epoch,
        batches.len() as u64,
        "the post-stream drain must absorb every batch"
    );
    assert_eq!(rep_sim.mutations.len(), rep_thr.mutations.len());
    for (a, b) in rep_sim.mutations.iter().zip(&rep_thr.mutations) {
        assert_eq!(a.batch_id, b.batch_id);
        assert_eq!(a.applied_tick, b.applied_tick, "batch {}: applied tick diverged", a.batch_id);
        assert_eq!(a.epoch_after, b.epoch_after, "batch {}: epoch diverged", a.batch_id);
        assert_eq!(a.ops, b.ops, "batch {}: applied op count diverged", a.batch_id);
        assert_eq!(
            a.service_ticks, b.service_ticks,
            "batch {}: mutation service cost diverged",
            a.batch_id
        );
    }
    let mut prev_epoch = 0;
    for (a, b) in rep_sim.results.iter().zip(&rep_thr.results) {
        assert_eq!(a.id, b.id, "dispatch order diverged");
        assert_eq!(a.wait_ticks, b.wait_ticks, "query {}: wait diverged", a.id);
        assert_eq!(a.service_ticks, b.service_ticks, "query {}: service diverged", a.id);
        assert_eq!(a.graph_epoch, b.graph_epoch, "query {}: epoch diverged", a.id);
        assert_eq!(a.bits, b.bits, "query {}: result bits diverged", a.id);
        assert!(a.graph_epoch >= prev_epoch, "epochs must be nondecreasing in dispatch order");
        prev_epoch = a.graph_epoch;
    }
    assert!(
        rep_sim.results.iter().any(|r| r.graph_epoch > 0),
        "the schedule must land queries after at least one mutation"
    );
}

#[test]
fn exact_kinds_match_true_fresh_ingest_of_mutated_edges() {
    let g = gen::barabasi_albert(500, 5, 19);
    let p = 4;
    let dg = ingest_once(&g, p, cost(), Placement::Spread);
    let mut engine = SpmdEngine::from_ingested(
        Cluster::new(p, cost()),
        dg,
        cost(),
        Flags::tdo_gp(),
        "delta-exact",
        QueryShard::new,
    );
    // Evolve the flat arc set alongside the engine.
    let mut arcs = det_map::<u64, f32>();
    for u in 0..g.n as Vid {
        for &(v, w) in g.neighbors(u) {
            arcs.insert(((u as u64) << 32) | v as u64, w);
        }
    }
    for b in &batches_for(&g, 3, 41) {
        engine.apply_delta(b);
        for op in &b.ops {
            match *op {
                EdgeOp::Insert { u, v, w } => {
                    arcs.insert(((u as u64) << 32) | v as u64, w);
                }
                EdgeOp::Delete { u, v } => {
                    arcs.remove(&(((u as u64) << 32) | v as u64));
                }
            }
        }
    }
    let mutated_g = Graph::from_arcs(
        g.n,
        arcs.iter()
            .map(|(&k, &w)| ((k >> 32) as Vid, (k & 0xFFFF_FFFF) as Vid, w))
            .collect(),
    );
    assert_eq!(mutated_g.m(), engine.meta().m, "mutated edge sets disagree");

    // A genuinely fresh ingestion places blocks differently, so only the
    // min/first-writer merges are comparable — and they must agree.
    let mut mutated = Server::new(engine, cfg());
    let mut fresh = Server::new(
        SpmdEngine::tdo_gp(Cluster::new(p, cost()), &mutated_g, cost(), QueryShard::new),
        cfg(),
    );
    for (id, kind) in [QueryKind::Bfs, QueryKind::Sssp, QueryKind::Cc].into_iter().enumerate() {
        let q = Query { id: id as u64, kind, source: 0, arrival: 0 };
        assert_eq!(
            mutated.run_query(&q),
            fresh.run_query(&q),
            "{kind:?}: delta-mutated engine != true fresh ingest of the mutated graph"
        );
    }
}
