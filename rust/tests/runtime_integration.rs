//! End-to-end integration over the PJRT runtime: load the AOT artifacts
//! produced by `make artifacts`, execute them from Rust, and verify the
//! numerics — the full L1 (Pallas) → L2 (JAX) → HLO text → L3 (Rust/PJRT)
//! chain.  Skipped (with a loud message) if artifacts are missing.

use tdorch::runtime::Engine;

fn engine() -> Option<Engine> {
    match Engine::load("artifacts") {
        Ok(e) => Some(e),
        Err(e) => {
            eprintln!("SKIP: artifacts not available ({e}); run `make artifacts`");
            None
        }
    }
}

#[test]
fn loads_all_manifest_artifacts() {
    let Some(engine) = engine() else { return };
    let names = engine.artifact_names();
    for expected in ["relax_batch", "spmv_panel", "ycsb_batch"] {
        assert!(names.contains(&expected), "missing {expected}: {names:?}");
    }
}

#[test]
fn ycsb_batch_numerics() {
    let Some(engine) = engine() else { return };
    let n = 1000; // deliberately not a multiple of the artifact batch
    let vals: Vec<f32> = (0..n).map(|i| i as f32 * 0.5).collect();
    let mul: Vec<f32> = (0..n).map(|i| 1.0 + (i % 3) as f32).collect();
    let add: Vec<f32> = (0..n).map(|i| (i % 7) as f32).collect();
    let out = engine.ycsb_batch(&vals, &mul, &add).unwrap();
    assert_eq!(out.len(), n);
    for i in 0..n {
        let want = vals[i] * mul[i] + add[i];
        assert!(
            (out[i] - want).abs() <= want.abs() * 1e-5 + 1e-5,
            "i={i}: {} vs {want}",
            out[i]
        );
    }
}

#[test]
fn ycsb_batch_larger_than_one_artifact_batch() {
    let Some(engine) = engine() else { return };
    let n = 4096 * 2 + 123;
    let vals = vec![2.0f32; n];
    let mul = vec![3.0f32; n];
    let add = vec![1.0f32; n];
    let out = engine.ycsb_batch(&vals, &mul, &add).unwrap();
    assert_eq!(out.len(), n);
    assert!(out.iter().all(|v| (*v - 7.0).abs() < 1e-6));
}

#[test]
fn relax_batch_numerics() {
    let Some(engine) = engine() else { return };
    let dv = vec![5.0f32, 1.0, 10.0, 0.5];
    let du = vec![1.0f32, 2.0, 3.0, 4.0];
    let w = vec![1.0f32, 1.0, 1.0, 1.0];
    let out = engine.relax_batch(&dv, &du, &w).unwrap();
    assert_eq!(out, vec![2.0, 1.0, 4.0, 0.5]);
}

#[test]
fn spmv_panel_numerics() {
    let Some(engine) = engine() else { return };
    let (inputs, output) = engine.shapes("spmv_panel").unwrap();
    let (m, k) = (inputs[0].0[0], inputs[0].0[1]);
    let panel = inputs[1].0[1];
    assert_eq!(output.0, vec![m, panel]);

    // A = 2*I (k = m), X = panel of ones: out = alpha*2 + beta everywhere.
    assert_eq!(m, k);
    let mut a = vec![0f32; m * k];
    for i in 0..m {
        a[i * k + i] = 2.0;
    }
    let x = vec![1f32; k * panel];
    let (alpha, beta) = (0.85f32, 0.15f32);
    let out = engine.spmv_panel(&a, &x, alpha, beta).unwrap();
    assert_eq!(out.len(), m * panel);
    for v in &out {
        assert!((*v - (alpha * 2.0 + beta)).abs() < 1e-5, "{v}");
    }
}

#[test]
fn kv_app_xla_path_matches_native() {
    // The KV store's Phase-3 lambda served by the Pallas artifact must
    // produce the same store as the native path.
    use tdorch::kvstore::{preload, Bucket, KvApp, KvOp};
    use tdorch::orchestration::tdorch::TdOrch;
    use tdorch::orchestration::{spread_tasks, Scheduler, Task};
    use tdorch::{Cluster, CostModel, DistStore};

    let Some(engine) = engine() else { return };
    let buckets = 64;
    let p = 4;
    let ops: Vec<Task<KvOp>> = (0..3000u64)
        .map(|i| {
            let op = if i % 4 == 0 {
                KvOp::read(i % 100, i)
            } else {
                KvOp::update(i % 100, i, 1.25, 2.0)
            };
            Task::inplace(op.bucket(buckets), op)
        })
        .collect();
    let spread = spread_tasks(ops, p);

    let run = |app: &KvApp| {
        let mut store: DistStore<Bucket> = DistStore::new(p);
        preload(&mut store, buckets, 100);
        let mut cluster = Cluster::new(p, CostModel::paper_cluster());
        TdOrch::new().run_stage(&mut cluster, app, spread.clone(), &mut store);
        let mut snap = store.snapshot();
        for (_, b) in &mut snap {
            b.sort_by_key(|(k, _)| *k);
        }
        snap
    };

    let native = run(&KvApp::new(buckets));
    let xla_app = KvApp::with_engine(buckets, &engine);
    let xla = run(&xla_app);
    assert!(xla_app.xla_served() >= 3000, "XLA served {}", xla_app.xla_served());

    assert_eq!(native.len(), xla.len());
    for ((a_addr, a_bucket), (b_addr, b_bucket)) in native.iter().zip(&xla) {
        assert_eq!(a_addr, b_addr);
        assert_eq!(a_bucket.len(), b_bucket.len());
        for ((ka, va), (kb, vb)) in a_bucket.iter().zip(b_bucket) {
            assert_eq!(ka, kb);
            assert!((va - vb).abs() <= va.abs() * 1e-4 + 1e-4, "{va} vs {vb}");
        }
    }
}
