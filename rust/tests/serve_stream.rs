//! The query-stream generator's contract: machine-count independence,
//! seed determinism, skew fidelity to the requested Zipf exponent, and
//! the server's deterministic bounded-queue admission.

use tdorch::graph::gen;
use tdorch::graph::spmd::SpmdEngine;
use tdorch::graph::Vid;
use tdorch::serve::{QueryShard, RunOpts, ServeConfig, Server};
use tdorch::workload::{
    generate_stream, hot_source_order, OpenLoopSource, QueryMix, StreamConfig, Zipf,
};
use tdorch::{Cluster, CostModel};

fn cost() -> CostModel {
    CostModel::paper_cluster()
}

#[test]
fn stream_is_identical_across_machine_counts() {
    // The generator sees only graph-derived hotness, never the
    // deployment: engines at P=1 and P=8 expose the same degree array,
    // hence the same hot order, hence byte-identical streams for one
    // seed.
    let g = gen::barabasi_albert(800, 5, 13);
    let orders: Vec<Vec<Vid>> = [1usize, 8]
        .iter()
        .map(|&p| {
            let e = SpmdEngine::tdo_gp(Cluster::new(p, cost()), &g, cost(), QueryShard::new);
            hot_source_order(&e.meta().out_deg)
        })
        .collect();
    assert_eq!(orders[0], orders[1], "hot order must not depend on P");
    let cfg = StreamConfig {
        queries: 200,
        per_tick: 3,
        every_ticks: 1,
        zipf_s: 1.2,
        mix: QueryMix::balanced(),
    };
    let a = generate_stream(cfg, &orders[0], 42);
    let b = generate_stream(cfg, &orders[1], 42);
    assert_eq!(a, b, "same seed must give the same stream at every P");
    let c = generate_stream(cfg, &orders[0], 43);
    assert_ne!(a, c, "different seeds must diverge");
}

#[test]
fn stream_skew_tracks_requested_exponent() {
    let n = 1000usize;
    let hot: Vec<Vid> = (0..n as Vid).collect();
    let mass_of = |s: f64| {
        let cfg = StreamConfig {
            queries: 40_000,
            per_tick: 8,
            every_ticks: 1,
            zipf_s: s,
            mix: QueryMix::balanced(),
        };
        let stream = generate_stream(cfg, &hot, 9);
        stream.iter().filter(|q| q.source == hot[0]).count() as f64 / stream.len() as f64
    };
    for s in [1.2f64, 2.5] {
        let got = mass_of(s);
        let expect = Zipf::new(n, s).p_hot();
        // 40k samples put the 3σ band well under 2% relative; 10% is a
        // loose functional tolerance, not a statistical knife edge.
        assert!(
            (got - expect).abs() / expect < 0.10,
            "s={s}: hottest-source mass {got:.4}, expected {expect:.4}"
        );
    }
    assert!(
        mass_of(2.5) > mass_of(1.2),
        "higher exponent must concentrate more traffic on the hottest source"
    );
}

#[test]
fn bounded_queue_rejects_overflow_deterministically() {
    // 32 queries burst into a 4-deep admission queue in one tick: the
    // overflow must be shed (open loop), and two identical runs must
    // agree on exactly which queries were served, their waits, batches
    // and results.
    let g = gen::barabasi_albert(300, 4, 2);
    let serve_cfg = ServeConfig {
        batch: 4,
        deadline_ticks: 1,
        queue_cap: 4,
        pr_iters: 2,
        ..ServeConfig::default()
    };
    let hot = {
        let e = SpmdEngine::tdo_gp(Cluster::new(2, cost()), &g, cost(), QueryShard::new);
        hot_source_order(&e.meta().out_deg)
    };
    let stream = generate_stream(
        StreamConfig {
            queries: 32,
            per_tick: 32,
            every_ticks: 1,
            zipf_s: 1.5,
            mix: QueryMix::balanced(),
        },
        &hot,
        5,
    );
    let run = || {
        let mut s = Server::new(
            SpmdEngine::tdo_gp(Cluster::new(2, cost()), &g, cost(), QueryShard::new),
            serve_cfg,
        );
        s.serve(&mut OpenLoopSource::new(&stream), RunOpts::default())
    };
    let a = run();
    assert!(a.rejected > 0, "a 32-query burst must overflow a 4-deep queue");
    assert_eq!(a.served() as u64 + a.rejected, 32, "every arrival is served or rejected");
    let b = run();
    assert_eq!(a.rejected, b.rejected);
    assert_eq!(a.batches, b.batches);
    assert_eq!(a.ticks, b.ticks);
    let ids = |r: &tdorch::serve::ServeReport| -> Vec<(u64, u64, u64)> {
        r.results.iter().map(|x| (x.id, x.wait_ticks, x.batch)).collect()
    };
    assert_eq!(ids(&a), ids(&b), "admission/batching must be deterministic");
    for (x, y) in a.results.iter().zip(&b.results) {
        assert_eq!(x.bits, y.bits, "query {}: bits diverged between identical runs", x.id);
    }
}

#[test]
fn deadline_dispatches_partial_batches() {
    // A slow trickle against batch=8 would starve without the tick
    // deadline.  Under the pipelined clock the deadline bounds the time
    // a partial batch sits waiting to CLOSE while the server is idle —
    // once service occupies the clock, later arrivals accrue wait at the
    // service rate — so the sharp guarantees are: the first batch's
    // head-of-line query waits exactly the deadline (the server is idle
    // before it), batches stay partial (smaller than the size trigger),
    // and nothing waits forever.
    let g = gen::barabasi_albert(300, 4, 2);
    let hot = {
        let e = SpmdEngine::tdo_gp(Cluster::new(2, cost()), &g, cost(), QueryShard::new);
        hot_source_order(&e.meta().out_deg)
    };
    // One arrival every 64 ticks: far slower than any query's service,
    // so the server drains completely between arrivals and EVERY query
    // is its batch's head of line.
    let stream = generate_stream(
        StreamConfig {
            queries: 6,
            per_tick: 1,
            every_ticks: 64,
            zipf_s: 1.5,
            mix: QueryMix::balanced(),
        },
        &hot,
        8,
    );
    let mut s = Server::new(
        SpmdEngine::tdo_gp(Cluster::new(2, cost()), &g, cost(), QueryShard::new),
        ServeConfig {
            batch: 8,
            deadline_ticks: 2,
            queue_cap: 16,
            pr_iters: 2,
            ..ServeConfig::default()
        },
    );
    let rep = s.serve(&mut OpenLoopSource::new(&stream), RunOpts::default());
    assert_eq!(rep.served(), 6);
    assert_eq!(rep.rejected, 0);
    assert_eq!(rep.batches, 6, "a drained server forms one partial batch per arrival");
    let waits: Vec<u64> = rep.results.iter().map(|r| r.wait_ticks).collect();
    // The last arrival exhausts the source, so the drain rule dispatches
    // it immediately instead of waiting out the deadline.
    assert_eq!(
        waits,
        vec![2, 2, 2, 2, 2, 0],
        "an idle server must close each partial batch exactly at the deadline"
    );
    assert!(
        rep.results.iter().all(|r| r.service_ticks >= 1),
        "service must occupy at least one logical tick"
    );
}
