//! The pipelined-admission contract under load:
//!
//! 1. **Schedule determinism across backends and runs** — for a fixed
//!    (source, config, graph, P), the full wait-tick / service-tick /
//!    rejection schedule is identical between the simulator and the
//!    threaded pool (P ∈ {1, 8}), because the service clock is driven by
//!    ledger-superstep deltas, which are pure functions of (graph,
//!    flags, P).
//! 2. **Overload regression** — with the queue at cap, pushing more
//!    offered load produces MORE rejections (never fewer), and every
//!    query that is served remains bit-identical to a fresh single-shot
//!    sim reference.
//! 3. **Pipelined admission is observable** — arrivals landing during a
//!    long batch's service window are admitted mid-batch (the old loop
//!    froze the clock for the whole batch, so waits could never exceed
//!    the deadline; under the service clock they must).
//! 4. The closed loop rides the same clock: sim == threaded schedules,
//!    and a population no larger than the queue cap is never shed.

use tdorch::exec::{Substrate, ThreadedCluster};
use tdorch::graph::flags::Flags;
use tdorch::graph::gen;
use tdorch::graph::spmd::{ingest_once, Placement, SpmdEngine};
use tdorch::graph::Graph;
use tdorch::serve::{QueryShard, RunOpts, ServeConfig, ServePolicy, ServeReport, Server};
use tdorch::workload::{
    generate_stream, hot_source_order, ClosedLoop, ClosedLoopConfig, OpenLoopSource, QueryMix,
    StreamConfig,
};
use tdorch::{Cluster, CostModel};

fn cost() -> CostModel {
    CostModel::paper_cluster()
}

fn cfg() -> ServeConfig {
    ServeConfig { batch: 4, queue_cap: 8, ..ServeConfig::default() }
}

fn stream_cfg(queries: usize, per_tick: usize, every_ticks: u64) -> StreamConfig {
    StreamConfig { queries, per_tick, every_ticks, zipf_s: 1.5, mix: QueryMix::balanced() }
}

/// The full deterministic schedule of a run, for exact comparison.
fn schedule(rep: &ServeReport) -> (u64, u64, u64, Vec<(u64, u64, u64, u64)>) {
    (
        rep.rejected,
        rep.batches,
        rep.ticks,
        rep.results
            .iter()
            .map(|r| (r.id, r.wait_ticks, r.service_ticks, r.batch))
            .collect(),
    )
}

fn sim_server(g: &Graph, p: usize) -> Server<Cluster> {
    Server::new(
        SpmdEngine::tdo_gp(Cluster::new(p, cost()), g, cost(), QueryShard::new),
        cfg(),
    )
}

#[test]
fn pipelined_schedule_identical_sim_vs_threaded_at_p1_and_p8() {
    let g = gen::barabasi_albert(600, 5, 11);
    for p in [1usize, 8] {
        let dg = ingest_once(&g, p, cost(), Placement::Spread);
        let mut sim = Server::new(
            SpmdEngine::from_ingested(
                Cluster::new(p, cost()),
                dg.clone(),
                cost(),
                Flags::tdo_gp(),
                "load-sim",
                QueryShard::new,
            ),
            cfg(),
        );
        let mut thr = Server::new(
            SpmdEngine::from_ingested(
                ThreadedCluster::new(p),
                dg,
                cost(),
                Flags::tdo_gp(),
                "load-threaded",
                QueryShard::new,
            ),
            cfg(),
        );
        let hot = hot_source_order(&sim.engine().meta().out_deg);
        // Overloaded (2 q/tick vs a sub-1/tick service rate) so waits,
        // service windows AND rejections are all exercised.
        let stream = generate_stream(stream_cfg(40, 2, 1), &hot, 13);
        let rep_sim = sim.serve(&mut OpenLoopSource::new(&stream), RunOpts::default());
        let rep_thr = thr.serve(&mut OpenLoopSource::new(&stream), RunOpts::default());
        assert!(rep_sim.rejected > 0, "P={p}: the overload stream must shed some load");
        assert_eq!(
            schedule(&rep_sim),
            schedule(&rep_thr),
            "P={p}: wait/service/rejection schedule diverged between backends"
        );
        for (a, b) in rep_sim.results.iter().zip(&rep_thr.results) {
            assert_eq!(a.bits, b.bits, "P={p}: query {} bits diverged", a.id);
        }
        // Same backend, same inputs, run again on a REUSED engine: the
        // schedule is a pure function, not a warm-up artifact.
        let rep_sim2 = sim.serve(&mut OpenLoopSource::new(&stream), RunOpts::default());
        assert_eq!(
            schedule(&rep_sim),
            schedule(&rep_sim2),
            "P={p}: repeated run diverged on a reused engine"
        );
    }
}

#[test]
fn overload_rejections_grow_with_offered_load_and_results_stay_exact() {
    let g = gen::barabasi_albert(500, 5, 7);
    let p = 2;
    // Three offered rates spanning under- to heavily-overloaded, served
    // back to back on ONE engine (rates in queries/tick: 1/16, 1, 4).
    let rates = [(1usize, 16u64), (1, 1), (4, 1)];
    let mut server = sim_server(&g, p);
    // ONE reusable reference server (reset == fresh is pinned bit-for-bit
    // by tests/serve_equivalence.rs; rebuilding an ingested engine per
    // query would re-pay placement ~100 times here for no coverage).
    let mut reference = sim_server(&g, p);
    let hot = hot_source_order(&server.engine().meta().out_deg);
    let mut rejected = Vec::new();
    for (per_tick, every_ticks) in rates {
        let stream = generate_stream(stream_cfg(32, per_tick, every_ticks), &hot, 5);
        let rep = server.serve(&mut OpenLoopSource::new(&stream), RunOpts::default());
        assert_eq!(
            rep.served() as u64 + rep.rejected,
            32,
            "every arrival is served or rejected"
        );
        // Served queries stay bit-identical to single-shot references
        // even while the queue is shedding (reverse order so cross-query
        // leaks cannot cancel).
        for r in rep.results.iter().rev() {
            let fresh = reference.run_query(&stream[r.id as usize]);
            assert_eq!(
                r.bits, fresh,
                "rate {per_tick}/{every_ticks}: query {} diverged under overload",
                r.id
            );
        }
        rejected.push(rep.rejected);
    }
    assert_eq!(rejected[0], 0, "1/16 q/tick is far below service capacity");
    assert!(
        rejected.windows(2).all(|w| w[0] <= w[1]),
        "rejections must be nondecreasing in offered load: {rejected:?}"
    );
    assert!(
        rejected[2] > rejected[1],
        "quadrupling an already-saturating offered load must shed strictly more: {rejected:?}"
    );
    assert!(rejected[2] > 0, "4 q/tick against a cap-8 queue must shed");
}

#[test]
fn admission_happens_during_batch_service() {
    // 8 queries burst at tick 0 (filling the cap-8 queue and closing a
    // full batch of 4) and 8 more arrive one per tick.  The old loop
    // froze the clock while the batch executed — the trailing arrivals
    // were all admitted "at once" after it and no wait could exceed
    // deadline + batch position.  Under the pipelined clock the first
    // batch's service occupies ticks, so the trailing arrivals are
    // admitted mid-batch and the later ones observe REAL queueing: some
    // query must wait longer than deadline_ticks + batch size, which is
    // impossible with frozen-clock admission.
    let g = gen::barabasi_albert(400, 5, 3);
    // A deliberately slow service clock (4 ledger supersteps per tick)
    // so even the cheapest query occupies several ticks — the wait bound
    // below is then structural, not a race against fast queries.
    let scfg = ServeConfig { supersteps_per_tick: 4, ..cfg() };
    let mut server = Server::new(
        SpmdEngine::tdo_gp(Cluster::new(2, cost()), &g, cost(), QueryShard::new),
        scfg,
    );
    let hot = hot_source_order(&server.engine().meta().out_deg);
    let mut stream = generate_stream(stream_cfg(16, 8, 1), &hot, 17);
    for (i, q) in stream.iter_mut().enumerate() {
        q.arrival = if i < 8 { 0 } else { (i - 7) as u64 };
    }
    let rep = server.run(&stream);
    assert_eq!(rep.served() as u64 + rep.rejected, 16);
    assert!(rep.served() >= 8, "the burst itself fits the queue");
    let max_wait = rep.results.iter().map(|r| r.wait_ticks).max().unwrap();
    assert!(
        max_wait > scfg.deadline_ticks + scfg.batch as u64,
        "service must occupy logical time: max wait {max_wait} looks like the \
         frozen-clock admission loop"
    );
    // Ticks span at least the total service: the clock really advanced
    // through every query's window.
    let total_service: u64 = rep.results.iter().map(|r| r.service_ticks).sum();
    assert!(
        rep.ticks >= total_service,
        "run span {} cannot be shorter than total service {total_service}",
        rep.ticks
    );
}

#[test]
fn closed_loop_schedule_identical_sim_vs_threaded() {
    let g = gen::barabasi_albert(500, 5, 19);
    let p = 4;
    let dg = ingest_once(&g, p, cost(), Placement::Spread);
    let mut sim = Server::new(
        SpmdEngine::from_ingested(
            Cluster::new(p, cost()),
            dg.clone(),
            cost(),
            Flags::tdo_gp(),
            "closed-sim",
            QueryShard::new,
        ),
        cfg(),
    );
    let mut thr = Server::new(
        SpmdEngine::from_ingested(
            ThreadedCluster::new(p),
            dg,
            cost(),
            Flags::tdo_gp(),
            "closed-threaded",
            QueryShard::new,
        ),
        cfg(),
    );
    let hot = hot_source_order(&sim.engine().meta().out_deg);
    let ccfg = ClosedLoopConfig {
        clients: 6,
        think_ticks: 3,
        queries_per_client: 4,
        zipf_s: 1.5,
        mix: QueryMix::balanced(),
    };
    let mut src_sim = ClosedLoop::new(ccfg, &hot, 23);
    let mut src_thr = ClosedLoop::new(ccfg, &hot, 23);
    let rep_sim = sim.serve(&mut src_sim, RunOpts::default());
    let rep_thr = thr.serve(&mut src_thr, RunOpts::default());
    assert_eq!(rep_sim.offered(), 24, "6 clients x 4 queries");
    assert_eq!(
        rep_sim.rejected, 0,
        "6 clients with <=1 outstanding each can never overflow a cap-8 queue"
    );
    assert_eq!(
        schedule(&rep_sim),
        schedule(&rep_thr),
        "closed-loop schedule diverged between backends"
    );
    assert_eq!(
        src_sim.emitted(),
        src_thr.emitted(),
        "the two populations must have issued identical query sequences"
    );
    for (a, b) in rep_sim.results.iter().zip(&rep_thr.results) {
        assert_eq!(a.bits, b.bits, "closed-loop query {} bits diverged", a.id);
    }
}

#[test]
fn service_clock_is_ledger_supersteps_over_rate() {
    // Doubling supersteps_per_tick must (weakly) shrink every query's
    // service_ticks and never change which queries are served vs
    // rejected for an underloaded trickle; and the recorded service
    // ticks must obey the ceil formula's bounds (>= 1 always).
    let g = gen::barabasi_albert(400, 4, 2);
    let hot = {
        let e = SpmdEngine::tdo_gp(Cluster::new(2, cost()), &g, cost(), QueryShard::new);
        hot_source_order(&e.meta().out_deg)
    };
    let stream = generate_stream(stream_cfg(8, 1, 64), &hot, 29);
    let run_with_rate = |rate: u64| {
        let mut s = Server::new(
            SpmdEngine::tdo_gp(Cluster::new(2, cost()), &g, cost(), QueryShard::new),
            ServeConfig { supersteps_per_tick: rate, ..cfg() },
        );
        s.serve(&mut OpenLoopSource::new(&stream), RunOpts::default())
    };
    let slow = run_with_rate(1);
    let fast = run_with_rate(64);
    assert_eq!(slow.served(), 8);
    assert_eq!(fast.served(), 8);
    for (a, b) in slow.results.iter().zip(&fast.results) {
        assert_eq!(a.id, b.id, "an underloaded trickle serves in arrival order");
        assert!(a.service_ticks >= b.service_ticks, "a slower clock cannot shrink service");
        assert!(b.service_ticks >= 1, "service occupies at least one tick");
        assert_eq!(a.bits, b.bits, "the service clock must not affect results");
        // rate=1 makes service_ticks == the ledger-superstep delta
        // itself; a graph query does real work, so it must be > 1.
        assert!(a.service_ticks > 1, "query {} consumed no ledger supersteps?", a.id);
    }
    assert!(slow.ticks > fast.ticks, "total span scales with the service clock");
}

// ---- ServeReport accounting under fusion + memoization (PR 7) ----

#[test]
fn served_is_exactly_hits_plus_misses_and_waves_cover_every_miss() {
    let g = gen::barabasi_albert(500, 5, 7);
    let mut server = Server::new(
        SpmdEngine::tdo_gp(Cluster::new(2, cost()), &g, cost(), QueryShard::new),
        cfg(),
    )
    .with_serving_policy(ServePolicy::new().with_fuse(true).with_cache(true));
    let hot = hot_source_order(&server.engine().meta().out_deg);
    // A hot Zipf stream so the cache actually engages.
    let stream = generate_stream(stream_cfg(32, 2, 1), &hot, 5);
    let rep = server.run(&stream);
    assert_eq!(
        rep.served() as u64,
        rep.cache_hits + rep.cache_misses,
        "every served query is exactly one of hit or miss"
    );
    assert!(rep.cache_hits > 0, "a Zipf stream with CC/PR in the mix must repeat a key");
    let cached = rep.results.iter().filter(|r| r.cached).count() as u64;
    assert_eq!(cached, rep.cache_hits, "the cached flag and the hit counter must agree");
    for r in &rep.results {
        if r.cached {
            assert_eq!(r.service_ticks, 0, "query {}: a hit costs no service", r.id);
        } else {
            assert!(r.service_ticks >= 1, "query {}: a miss occupies the engine", r.id);
        }
    }
    // Waves partition the misses: every engine-executed query sits in
    // exactly one wave, and hits sit in none.
    let lanes_total: usize = rep.waves.iter().map(|w| w.lanes).sum();
    assert_eq!(lanes_total as u64, rep.cache_misses);
    let mut ids: Vec<u64> = rep.waves.iter().flat_map(|w| w.query_ids.clone()).collect();
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(ids.len() as u64, rep.cache_misses, "no query appears in two waves");
    // And with both knobs off, the same stream is all misses, no waves
    // wider than one lane.
    let mut plain = sim_server(&g, 2);
    let rep0 = plain.serve(&mut OpenLoopSource::new(&stream), RunOpts::default());
    assert_eq!(rep0.cache_hits, 0);
    assert_eq!(rep0.cache_misses, rep0.served() as u64);
    assert!(rep0.waves.iter().all(|w| w.lanes == 1));
}

#[test]
fn rejection_monotonicity_survives_fusion() {
    // The overload ramp of `overload_rejections_grow...`, served with
    // fusion ON (cache off, to isolate fusion's effect on the schedule):
    // shedding must still be nondecreasing in offered load.
    let g = gen::barabasi_albert(500, 5, 7);
    let mut server = Server::new(
        SpmdEngine::tdo_gp(Cluster::new(2, cost()), &g, cost(), QueryShard::new),
        cfg(),
    )
    .with_serving_policy(ServePolicy::new().with_fuse(true));
    let hot = hot_source_order(&server.engine().meta().out_deg);
    let mut rejected = Vec::new();
    for (per_tick, every_ticks) in [(1usize, 16u64), (1, 1), (4, 1)] {
        let stream = generate_stream(stream_cfg(32, per_tick, every_ticks), &hot, 5);
        let rep = server.serve(&mut OpenLoopSource::new(&stream), RunOpts::default());
        assert_eq!(rep.served() as u64 + rep.rejected, 32);
        rejected.push(rep.rejected);
    }
    assert!(
        rejected.windows(2).all(|w| w[0] <= w[1]),
        "fused rejections must be nondecreasing in offered load: {rejected:?}"
    );
    assert!(rejected[2] > 0, "4 q/tick against a cap-8 queue must still shed");
}

#[test]
fn fused_wave_ticks_never_exceed_sum_of_single_shot_ticks() {
    // The amortization inequality: a fused wave's service_ticks is at
    // most the sum its members would have cost dispatched one by one
    // (lanes share every superstep, so per-round cost is the max over
    // lanes, not the sum).  Measured, not assumed: each member is
    // re-run single-shot on a reference engine and priced by the same
    // ledger-delta formula.
    let g = gen::barabasi_albert(500, 5, 23);
    let p = 2;
    let scfg = cfg();
    let mut server = Server::new(
        SpmdEngine::tdo_gp(Cluster::new(p, cost()), &g, cost(), QueryShard::new),
        scfg,
    )
    .with_serving_policy(ServePolicy::new().with_fuse(true));
    let mut reference = sim_server(&g, p);
    let hot = hot_source_order(&server.engine().meta().out_deg);
    // Single-kind streams guarantee max-width waves for every fusable
    // kind; the deadline burst pattern closes full batches.
    for (kind_mix, label) in [
        (QueryMix { bfs: 1, sssp: 0, pr: 0, cc: 0, bc: 0 }, "bfs"),
        (QueryMix { bfs: 0, sssp: 1, pr: 0, cc: 0, bc: 0 }, "sssp"),
        (QueryMix { bfs: 0, sssp: 0, pr: 0, cc: 1, bc: 0 }, "cc"),
    ] {
        let stream = generate_stream(
            StreamConfig { queries: 8, per_tick: 4, every_ticks: 1, zipf_s: 1.5, mix: kind_mix },
            &hot,
            31,
        );
        let rep = server.serve(&mut OpenLoopSource::new(&stream), RunOpts::default());
        let fused: Vec<_> = rep.waves.iter().filter(|w| w.lanes >= 2).collect();
        assert!(!fused.is_empty(), "{label}: a single-kind burst must form a fused wave");
        for w in &fused {
            let mut single_sum = 0u64;
            for id in &w.query_ids {
                let s0 = reference.engine().sub().ledger_supersteps();
                reference.run_query(&stream[*id as usize]);
                let steps = reference.engine().sub().ledger_supersteps() - s0;
                single_sum += steps.div_ceil(scfg.supersteps_per_tick).max(1);
            }
            assert!(
                w.service_ticks <= single_sum,
                "{label}: a {}-lane wave cost {} ticks but its members cost {} solo",
                w.lanes,
                w.service_ticks,
                single_sum
            );
        }
    }
}
