//! The result-memoization wall (PR 7 tentpole, part b):
//!
//! 1. Repeated hot queries hit: the hit count equals the stream's repeat
//!    count (total minus distinct cache keys — CC/PR collapse onto one
//!    canonical key each), hits carry `cached` and zero service ticks.
//! 2. Cache-on and cache-off runs of the same stream serve bit-identical
//!    results.
//! 3. Under a mutating feed (`Server::serve` with `RunOpts::feed`), an epoch
//!    bump invalidates exactly the stale entries: every hit is backed by
//!    a same-epoch miss with identical bits (a pre-mutation result can
//!    never be served post-epoch), every result — hit or miss — matches
//!    a reference engine built at exactly its epoch, and repeats that
//!    span a bump are re-executed, then hit again at the new epoch.
//! 4. Regression (the `repro mutate` reference-walk fix):
//!    [`Server::run_query`] NEVER consults or fills the cache, so a
//!    reverse-order reference walk can never validate a result against a
//!    cached copy of itself.

use tdorch::graph::gen;
use tdorch::graph::ingest::DistGraph;
use tdorch::graph::spmd::{ingest_once, Placement, SpmdEngine};
use tdorch::graph::{Graph, Vid};
use tdorch::mutate::{generate_mutations, MutationConfig, MutationFeed};
use tdorch::serve::{canonical_source, QueryShard, RunOpts, ServeConfig, ServePolicy, Server};
use tdorch::workload::{hot_source_order, OpenLoopSource, Query, QueryKind};
use tdorch::{Cluster, CostModel};

fn cost() -> CostModel {
    CostModel::paper_cluster()
}

fn query(id: u64, kind: QueryKind, source: Vid, arrival: u64) -> Query {
    Query { id, kind, source, arrival }
}

fn server(g: &Graph, cache: bool) -> Server<Cluster> {
    Server::new(
        SpmdEngine::tdo_gp(Cluster::new(2, cost()), g, cost(), QueryShard::new),
        ServeConfig { batch: 4, ..ServeConfig::default() },
    )
    .with_serving_policy(ServePolicy::new().with_cache(cache))
}

/// A burst stream with known repeats: 5 distinct cache keys in 10
/// queries (CC and PR queries share one canonical key each regardless of
/// their nominal source).
fn repeat_stream() -> Vec<Query> {
    vec![
        query(0, QueryKind::Bfs, 3, 0),
        query(1, QueryKind::Bfs, 3, 0),
        query(2, QueryKind::Cc, 1, 0),
        query(3, QueryKind::Sssp, 7, 0),
        query(4, QueryKind::Cc, 200, 0),
        query(5, QueryKind::Bfs, 3, 0),
        query(6, QueryKind::Pr, 0, 0),
        query(7, QueryKind::Sssp, 7, 0),
        query(8, QueryKind::Pr, 150, 0),
        query(9, QueryKind::Bc, 5, 0),
    ]
}

#[test]
fn repeated_queries_hit_exactly_repeat_count_times() {
    let g = gen::barabasi_albert(400, 5, 11);
    let mut srv = server(&g, true);
    let rep = srv.serve(&mut OpenLoopSource::new(&repeat_stream()), RunOpts::default());
    assert_eq!(rep.served(), 10, "queue cap 64 sheds nothing here");
    // 10 queries, 5 distinct keys {BFS@3, CC, SSSP@7, PR, BC@5}: ids
    // 1, 4, 5, 7, 8 are repeats and must ALL hit — 4 and 8 via source
    // canonicalization (CC/PR ignore their nominal source).
    assert_eq!(rep.cache_hits, 5, "hit count must equal the stream's repeat count");
    assert_eq!(rep.cache_misses, 5, "one miss per distinct key");
    assert_eq!(srv.cache_len(), 5, "one entry per distinct key");
    for r in &rep.results {
        let expect_hit = matches!(r.id, 1 | 4 | 5 | 7 | 8);
        assert_eq!(r.cached, expect_hit, "query {}: wrong cache outcome", r.id);
        if r.cached {
            assert_eq!(r.service_ticks, 0, "query {}: hits cost no service", r.id);
            assert_eq!(r.service_ms, 0.0, "query {}: hits run no engine pass", r.id);
        }
    }
}

#[test]
fn cache_on_and_off_serve_identical_bits() {
    let g = gen::barabasi_albert(400, 5, 13);
    let rep_on =
        server(&g, true).serve(&mut OpenLoopSource::new(&repeat_stream()), RunOpts::default());
    let rep_off =
        server(&g, false).serve(&mut OpenLoopSource::new(&repeat_stream()), RunOpts::default());
    assert_eq!(rep_off.cache_hits, 0);
    assert_eq!(rep_on.served(), rep_off.served());
    for (a, b) in rep_on.results.iter().zip(&rep_off.results) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.bits, b.bits, "query {}: memoization changed the bits", a.id);
    }
}

#[test]
fn epoch_bump_invalidates_stale_entries_and_never_serves_old_bits() {
    let g = gen::barabasi_albert(400, 5, 17);
    let p = 2;
    let dg = ingest_once(&g, p, cost(), Placement::Spread);
    let hot = hot_source_order(&dg.out_deg);
    // Repeats of three hot keys, one arrival per tick, spanning both
    // mutation arrivals (ticks 4 and 14) — so the same key is cached,
    // invalidated, recomputed and re-hit.
    let kinds: [(QueryKind, Vid); 5] = [
        (QueryKind::Bfs, 3),
        (QueryKind::Sssp, 7),
        (QueryKind::Cc, 1),
        (QueryKind::Bfs, 3),
        (QueryKind::Sssp, 7),
    ];
    let stream: Vec<Query> = (0..20)
        .map(|i| {
            let (kind, src) = kinds[i % kinds.len()];
            query(i as u64, kind, src, i as u64)
        })
        .collect();
    let batches = generate_mutations(
        MutationConfig {
            batches: 2,
            ops_per_batch: 8,
            insert_pct: 60,
            zipf_s: 1.2,
            start_tick: 4,
            every_ticks: 10,
        },
        &g,
        &hot,
        23,
    );
    let mut srv = Server::new(
        SpmdEngine::from_ingested(
            Cluster::new(p, cost()),
            dg.clone(),
            cost(),
            tdorch::graph::flags::Flags::tdo_gp(),
            "cache-mutating",
            QueryShard::new,
        ),
        ServeConfig { batch: 4, ..ServeConfig::default() },
    )
    .with_serving_policy(ServePolicy::new().with_cache(true));
    let mut feed = MutationFeed::new(batches.clone());
    let rep = srv.serve(&mut OpenLoopSource::new(&stream), RunOpts::new().feed(&mut feed));
    assert_eq!(rep.graph_epoch, 2, "both delta batches must absorb");
    assert_eq!(rep.served() as u64, rep.cache_hits + rep.cache_misses);

    // (a) No hit ever crosses an epoch: every cached result must be
    // backed by an EARLIER engine-executed result with the same key at
    // the SAME epoch and identical bits.
    for (i, r) in rep.results.iter().enumerate() {
        if !r.cached {
            continue;
        }
        let donor = rep.results[..i].iter().rev().find(|d| {
            !d.cached
                && d.kind == r.kind
                && canonical_source(d.kind, d.source) == canonical_source(r.kind, r.source)
                && d.graph_epoch == r.graph_epoch
        });
        let donor = donor.unwrap_or_else(|| {
            panic!(
                "query {}: hit at epoch {} with no same-epoch miss before it — \
                 a stale entry was served",
                r.id, r.graph_epoch
            )
        });
        assert_eq!(donor.bits, r.bits, "query {}: hit bits differ from the donor's", r.id);
    }

    // (b) Ground truth: every result — hit or miss — matches a
    // reference engine built at exactly its epoch (replayed placement,
    // cache off; reverse walk as everywhere).
    let mut dgs: Vec<DistGraph> = vec![dg];
    for b in &batches {
        let mut next = dgs.last().unwrap().clone();
        next.apply_batch(b);
        dgs.push(next);
    }
    let mut refs: Vec<Option<Server<Cluster>>> = (0..dgs.len()).map(|_| None).collect();
    for r in rep.results.iter().rev() {
        let e = r.graph_epoch as usize;
        let srv = refs[e].get_or_insert_with(|| {
            Server::new(
                SpmdEngine::from_ingested(
                    Cluster::new(p, cost()),
                    dgs[e].clone(),
                    cost(),
                    tdorch::graph::flags::Flags::tdo_gp(),
                    "cache-epoch-ref",
                    QueryShard::new,
                ),
                ServeConfig { batch: 4, ..ServeConfig::default() },
            )
        });
        let q = query(r.id, r.kind, r.source, 0);
        assert_eq!(
            srv.run_query(&q),
            r.bits,
            "query {} (epoch {}): served bits differ from that epoch's reference",
            r.id,
            r.graph_epoch
        );
    }

    // (c) The bump really invalidated: some key cached at an earlier
    // epoch was re-EXECUTED (a miss) after the bump, and the cache kept
    // paying off afterwards (a hit at epoch > 0).
    let recomputed = rep.results.iter().any(|r| {
        !r.cached
            && r.graph_epoch > 0
            && rep.results.iter().any(|d| {
                !d.cached
                    && d.kind == r.kind
                    && canonical_source(d.kind, d.source) == canonical_source(r.kind, r.source)
                    && d.graph_epoch < r.graph_epoch
            })
    });
    assert!(recomputed, "no repeated key was re-executed after an epoch bump");
    assert!(
        rep.results.iter().any(|r| r.cached && r.graph_epoch > 0),
        "the cache must engage again at the new epoch"
    );
}

#[test]
fn run_query_never_touches_the_cache() {
    // The `repro mutate` regression: the reverse-order reference walk
    // re-executes served queries through `run_query`; if that path read
    // or filled the cache, verification could compare a result against
    // a stored copy of itself.  Even on a cache-enabled server,
    // `run_query` must execute every call and leave the cache empty.
    let g = gen::barabasi_albert(400, 5, 19);
    let mut srv = server(&g, true);
    let q = query(0, QueryKind::Bfs, 3, 0);
    let resets0 = srv.engine().resets();
    let first = srv.run_query(&q);
    let second = srv.run_query(&q);
    let third = srv.run_query(&q);
    assert_eq!(first, second);
    assert_eq!(second, third);
    assert_eq!(
        srv.engine().resets(),
        resets0 + 3,
        "every run_query call must re-execute on the engine, repeats included"
    );
    assert_eq!(srv.cache_len(), 0, "run_query must not populate the cache");
}
