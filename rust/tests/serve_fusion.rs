//! The fused-wave bit-equality wall (PR 7 tentpole, part a):
//!
//! 1. For every fusable kind (BFS, SSSP, CC), a multi-source fused wave
//!    returns, lane by lane, EXACTLY the bits of a per-query single-shot
//!    run — across P ∈ {1, 2, 8} and on both substrates (sim and
//!    threaded), duplicate sources included.
//! 2. A one-lane wave degenerates to today's single-shot path
//!    bit-for-bit.
//! 3. Through the serving loop, a mixed-kind batch splits into
//!    single-kind waves: fusable kinds group, PR/BC stay solo, every
//!    member's bits still match the reference.

use tdorch::exec::ThreadedCluster;
use tdorch::graph::flags::Flags;
use tdorch::graph::gen;
use tdorch::graph::spmd::{ingest_once, Placement, SpmdEngine};
use tdorch::graph::{Graph, Vid};
use tdorch::serve::{fusable, QueryShard, RunOpts, ServeConfig, ServePolicy, Server};
use tdorch::workload::{OpenLoopSource, Query, QueryKind};
use tdorch::{Cluster, CostModel};

fn cost() -> CostModel {
    CostModel::paper_cluster()
}

fn query(id: u64, kind: QueryKind, source: Vid) -> Query {
    Query { id, kind, source, arrival: 0 }
}

fn sim_server(g: &Graph, p: usize) -> Server<Cluster> {
    Server::new(
        SpmdEngine::tdo_gp(Cluster::new(p, cost()), g, cost(), QueryShard::new),
        ServeConfig::default(),
    )
}

const EXACT_KINDS: [QueryKind; 3] = [QueryKind::Bfs, QueryKind::Sssp, QueryKind::Cc];

#[test]
fn fused_lanes_bit_equal_single_shot_across_p_and_backends() {
    let g = gen::barabasi_albert(500, 5, 11);
    // A duplicate source (3 twice) on purpose: with the cache off the
    // dispatch loop runs duplicates as duplicate lanes, so the engine
    // path must make them bit-equal, not the memoization.
    let sources: [Vid; 4] = [3, 41, 3, 199];
    for p in [1usize, 2, 8] {
        let dg = ingest_once(&g, p, cost(), Placement::Spread);
        let mut sim = Server::new(
            SpmdEngine::from_ingested(
                Cluster::new(p, cost()),
                dg.clone(),
                cost(),
                Flags::tdo_gp(),
                "fusion-sim",
                QueryShard::new,
            ),
            ServeConfig::default(),
        );
        let mut thr = Server::new(
            SpmdEngine::from_ingested(
                ThreadedCluster::new(p),
                dg,
                cost(),
                Flags::tdo_gp(),
                "fusion-threaded",
                QueryShard::new,
            ),
            ServeConfig::default(),
        );
        let mut reference = sim_server(&g, p);
        for kind in EXACT_KINDS {
            assert!(fusable(kind));
            let lanes_sim = sim.run_fused(kind, &sources);
            let lanes_thr = thr.run_fused(kind, &sources);
            assert_eq!(lanes_sim.len(), sources.len(), "one lane per source");
            assert_eq!(
                lanes_sim, lanes_thr,
                "P={p} {kind:?}: fused bits diverged between backends"
            );
            for (lane, &src) in lanes_sim.iter().zip(&sources) {
                let solo = reference.run_query(&query(0, kind, src));
                assert_eq!(
                    lane, &solo,
                    "P={p} {kind:?} source {src}: fused lane != single-shot bits"
                );
            }
        }
    }
}

#[test]
fn one_lane_wave_degenerates_to_the_single_shot_path() {
    let g = gen::barabasi_albert(400, 5, 13);
    let mut server = sim_server(&g, 2);
    let mut reference = sim_server(&g, 2);
    for kind in EXACT_KINDS {
        let fused = server.run_fused(kind, &[17]);
        assert_eq!(fused.len(), 1);
        assert_eq!(
            fused[0],
            reference.run_query(&query(0, kind, 17)),
            "{kind:?}: a single-lane wave must reproduce the solo path bit-for-bit"
        );
    }
}

#[test]
fn mixed_kind_batch_splits_into_single_kind_waves() {
    let g = gen::barabasi_albert(400, 5, 17);
    let mut server = Server::new(
        SpmdEngine::tdo_gp(Cluster::new(2, cost()), &g, cost(), QueryShard::new),
        ServeConfig { batch: 8, queue_cap: 16, ..ServeConfig::default() },
    )
    .with_serving_policy(ServePolicy::new().with_fuse(true));
    let mut reference = sim_server(&g, 2);
    // One burst batch mixing all five kinds, with repeats of the
    // fusable ones scattered between other kinds.
    let stream = vec![
        query(0, QueryKind::Bfs, 3),
        query(1, QueryKind::Pr, 0),
        query(2, QueryKind::Bfs, 41),
        query(3, QueryKind::Sssp, 7),
        query(4, QueryKind::Bc, 11),
        query(5, QueryKind::Cc, 0),
        query(6, QueryKind::Sssp, 99),
        query(7, QueryKind::Bfs, 120),
    ];
    let rep = server.serve(&mut OpenLoopSource::new(&stream), RunOpts::default());
    assert_eq!(rep.served(), 8);
    assert_eq!(rep.batches, 1, "one burst, one batch");
    // Head-of-line grouping: BFS gathers its three members, then the
    // non-fusable PR runs solo, then SSSP gathers two, BC solo, and the
    // lone CC is a one-lane wave.
    let shape: Vec<(QueryKind, usize)> = rep.waves.iter().map(|w| (w.kind, w.lanes)).collect();
    assert_eq!(
        shape,
        vec![
            (QueryKind::Bfs, 3),
            (QueryKind::Pr, 1),
            (QueryKind::Sssp, 2),
            (QueryKind::Bc, 1),
            (QueryKind::Cc, 1),
        ],
        "mixed batch must split into single-kind waves in head order"
    );
    for w in &rep.waves {
        for id in &w.query_ids {
            assert_eq!(
                stream[*id as usize].kind, w.kind,
                "wave of kind {:?} holds query {id} of another kind",
                w.kind
            );
        }
        assert!(
            fusable(w.kind) || w.lanes == 1,
            "{:?} is not fusable and must never share a wave",
            w.kind
        );
    }
    // And the split changed no bits: every member still equals its
    // single-shot reference (reverse order, as everywhere).
    for r in rep.results.iter().rev() {
        assert_eq!(
            r.bits,
            reference.run_query(&stream[r.id as usize]),
            "query {} diverged through the mixed-batch split",
            r.id
        );
    }
}
