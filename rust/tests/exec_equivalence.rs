//! Property-style cross-validation of the execution substrates: for
//! CounterApp, YCSB, and SSSP, across seeds {1, 42, 7} and P ∈ {1, 2, 8},
//! the *threaded* backend (real worker threads + channels), the BSP
//! *simulator*, and the *sequential oracle* must all produce identical
//! store state — and every scheduler must execute exactly the submitted
//! task count (the `StageOutcome::total_executed` invariant).

mod common;

use common::{random_tasks, CounterApp};
use tdorch::baselines::{DirectPull, DirectPush, SortingBased};
use tdorch::exec::apps::sssp_stages;
use tdorch::exec::ThreadedCluster;
use tdorch::kvstore::{preload, Bucket, KvApp, KvOp};
use tdorch::orchestration::tdorch::TdOrch;
use tdorch::orchestration::{sequential_reference, spread_tasks, Scheduler, Task};
use tdorch::rng::Rng;
use tdorch::workload::{YcsbKind, YcsbWorkload};
use tdorch::{Cluster, CostModel, DistStore};

const SEEDS: [u64; 3] = [1, 42, 7];
const PS: [usize; 3] = [1, 2, 8];

/// Run one scheduler on both substrates; assert both stores match the
/// oracle (under the app's normalization, e.g. bucket-order-insensitive
/// for YCSB) and both outcomes executed all submitted tasks.
fn check_both<A, K>(
    label: &str,
    app: &A,
    sim_sched: &dyn Scheduler<A>,
    thr_sched: &dyn Scheduler<A, ThreadedCluster>,
    tasks: &[Vec<Task<A::Ctx>>],
    seed_store: &DistStore<A::Val>,
    expected: &K,
    norm: impl Fn(&DistStore<A::Val>) -> K,
) where
    A: tdorch::OrchApp,
    K: PartialEq + std::fmt::Debug,
{
    let p = tasks.len();
    let n: u64 = tasks.iter().map(|b| b.len() as u64).sum();

    let mut cluster = Cluster::new(p, CostModel::paper_cluster());
    let mut sim_store = seed_store.clone();
    let sim = sim_sched.run_stage(&mut cluster, app, tasks.to_vec(), &mut sim_store);
    assert_eq!(
        &norm(&sim_store),
        expected,
        "{label}: simulator != sequential_reference (p={p})"
    );
    assert_eq!(sim.total_executed, n, "{label}: simulator executed count");

    let mut tc = ThreadedCluster::new(p);
    let mut thr_store = seed_store.clone();
    let thr = thr_sched.run_stage(&mut tc, app, tasks.to_vec(), &mut thr_store);
    assert_eq!(
        &norm(&thr_store),
        expected,
        "{label}: threaded != sequential_reference (p={p})"
    );
    assert_eq!(thr.total_executed, n, "{label}: threaded executed count");

    // The two substrates must agree on the load-balance object too: the
    // superstep delivery order is identical, so executed_per_machine is
    // bit-identical, not merely equivalent.
    assert_eq!(
        sim.executed_per_machine, thr.executed_per_machine,
        "{label}: per-machine execution diverged (p={p})"
    );
}

#[test]
fn counter_all_schedulers_all_substrates() {
    for seed in SEEDS {
        for p in PS {
            let mut rng = Rng::new(seed);
            let tasks = random_tasks(&mut rng, 600, 150, 0.6, true);
            let spread = spread_tasks(tasks, p);
            let app = CounterApp;
            let seed_store: DistStore<i64> = DistStore::new(p);
            let mut oracle = seed_store.clone();
            sequential_reference(&app, &spread, &mut oracle);
            let expected = oracle.snapshot();
            let norm = |s: &DistStore<i64>| s.snapshot();

            let td = TdOrch::new();
            check_both("counter/td", &app, &td, &td, &spread, &seed_store, &expected, norm);
            check_both(
                "counter/push", &app, &DirectPush, &DirectPush, &spread, &seed_store, &expected,
                norm,
            );
            check_both(
                "counter/pull", &app, &DirectPull, &DirectPull, &spread, &seed_store, &expected,
                norm,
            );
            check_both(
                "counter/sort", &app, &SortingBased, &SortingBased, &spread, &seed_store,
                &expected, norm,
            );
        }
    }
}

#[test]
fn ycsb_all_schedulers_all_substrates() {
    let buckets = 512u64;
    for seed in SEEDS {
        for p in PS {
            let workload = YcsbWorkload::new(YcsbKind::A, 20_000, 1.3, buckets);
            let mut rng = Rng::new(seed);
            let mut tasks: Vec<Vec<Task<KvOp>>> = (0..p).map(|_| Vec::new()).collect();
            for (m, batch) in tasks.iter_mut().enumerate() {
                *batch = workload.generate(&mut rng, 700, (m * 700) as u64);
            }
            let app = KvApp::new(buckets);
            let mut seed_store: DistStore<Bucket> = DistStore::new(p);
            preload(&mut seed_store, buckets, 3_000);
            let mut oracle = seed_store.clone();
            sequential_reference(&app, &tasks, &mut oracle);
            // Bucket vectors are insertion-ordered, so compare through
            // the canonical key-sorted bit-exact normalization.
            let norm = tdorch::kvstore::normalized_snapshot;
            let expected = norm(&oracle);

            let td = TdOrch::new();
            check_both("ycsb/td", &app, &td, &td, &tasks, &seed_store, &expected, norm);
            check_both(
                "ycsb/push", &app, &DirectPush, &DirectPush, &tasks, &seed_store, &expected,
                norm,
            );
            check_both(
                "ycsb/pull", &app, &DirectPull, &DirectPull, &tasks, &seed_store, &expected,
                norm,
            );
            check_both(
                "ycsb/sort", &app, &SortingBased, &SortingBased, &tasks, &seed_store, &expected,
                norm,
            );
        }
    }
}

#[test]
fn sssp_threaded_matches_simulator() {
    use tdorch::graph::gen;
    for seed in SEEDS {
        let g = gen::barabasi_albert(800, 5, seed);
        for p in PS {
            let td = TdOrch::new();
            let mut sim = Cluster::new(p, CostModel::paper_cluster());
            let dist_sim = sssp_stages(&mut sim, &td, &g, 0);
            let mut thr = ThreadedCluster::new(p);
            let dist_thr = sssp_stages(&mut thr, &td, &g, 0);
            assert_eq!(
                dist_sim, dist_thr,
                "sssp distances diverged (seed={seed}, p={p})"
            );
            // Threaded SSSP also goes through direct-pull: same answer.
            let mut thr2 = ThreadedCluster::new(p);
            let dist_pull = sssp_stages(&mut thr2, &DirectPull, &g, 0);
            assert_eq!(
                dist_sim, dist_pull,
                "sssp td-orch vs direct-pull diverged (seed={seed}, p={p})"
            );
        }
    }
}

#[test]
fn sssp_threaded_matches_graph_engine() {
    use tdorch::graph::algorithms::{sssp as engine_sssp, SsspShard};
    use tdorch::graph::gen;
    use tdorch::graph::spmd::SpmdEngine;

    let g = gen::barabasi_albert(1_000, 5, 42);
    let cost = CostModel::paper_cluster();
    let mut engine = SpmdEngine::tdo_gp(Cluster::new(8, cost), &g, cost, SsspShard::new);
    let expected = engine_sssp(&mut engine, 0);
    let mut tc = ThreadedCluster::new(8);
    let got = sssp_stages(&mut tc, &TdOrch::new(), &g, 0);
    assert_eq!(got.len(), expected.len());
    for (v, (a, b)) in got.iter().zip(&expected).enumerate() {
        assert!(
            a == b || (a.is_infinite() && b.is_infinite()),
            "vertex {v}: threaded {a} vs engine {b}"
        );
    }
}

#[test]
fn total_executed_counts_reads_too() {
    // Regression for the StageOutcome invariant: read-only ops produce no
    // write-back but still count as executed, on every scheduler and
    // both substrates.
    let buckets = 128u64;
    let p = 4;
    let tasks: Vec<Task<KvOp>> = (0..500u64)
        .map(|i| {
            let op = KvOp::read(i % 50, i);
            Task::inplace(op.bucket(buckets), op)
        })
        .collect();
    let spread = spread_tasks(tasks, p);
    let app = KvApp::new(buckets);

    let td = TdOrch::new();
    let sim_scheds: [&dyn Scheduler<KvApp>; 4] =
        [&td, &DirectPush, &DirectPull, &SortingBased];
    for sched in sim_scheds {
        let mut cluster = Cluster::new(p, CostModel::paper_cluster());
        let mut store: DistStore<Bucket> = DistStore::new(p);
        preload(&mut store, buckets, 100);
        let outcome = sched.run_stage(&mut cluster, &app, spread.clone(), &mut store);
        assert_eq!(outcome.total_executed, 500, "{} (simulator)", sched.name());
        assert_eq!(
            outcome.executed_per_machine.iter().sum::<u64>(),
            outcome.total_executed
        );
    }
    let thr_scheds: [&dyn Scheduler<KvApp, ThreadedCluster>; 4] =
        [&td, &DirectPush, &DirectPull, &SortingBased];
    for sched in thr_scheds {
        let mut tc = ThreadedCluster::new(p);
        let mut store: DistStore<Bucket> = DistStore::new(p);
        preload(&mut store, buckets, 100);
        let outcome = sched.run_stage(&mut tc, &app, spread.clone(), &mut store);
        assert_eq!(outcome.total_executed, 500, "{} (threaded)", sched.name());
    }
}

#[test]
fn threaded_metrics_mirror_populated() {
    // The threaded backend must fill the same ledger the simulator keeps:
    // per-machine executed counts, words moved, supersteps, wall-clock.
    let p = 4;
    let mut rng = Rng::new(9);
    let tasks = random_tasks(&mut rng, 2_000, 64, 0.5, false);
    let app = CounterApp;
    let mut tc = ThreadedCluster::new(p);
    let mut store: DistStore<i64> = DistStore::new(p);
    let outcome =
        TdOrch::new().run_stage(&mut tc, &app, spread_tasks(tasks, p), &mut store);
    assert_eq!(tc.metrics.executed_by_machine, outcome.executed_per_machine);
    assert!(tc.metrics.supersteps > 0);
    assert!(tc.metrics.total_words > 0, "no bytes moved over channels?");
    assert!(tc.max_busy_ms() > 0.0);
    assert_eq!(tc.busy_ms_by_machine().len(), p);
}
