//! The flight recorder's three contracts (PR 8 tentpole):
//!
//! 1. **One stream, two substrates.** The deterministic event cores —
//!    superstep ledger slices, admissions, rejections, batch closes,
//!    cache hits/misses, wave dispatches, completions, mutation applies
//!    — render bit-identically on the simulator and the threaded pool,
//!    at P=1 and P=8, for plain and mutating serving runs.  Wall-clock
//!    stays where it belongs: `Event::wall` is `None` everywhere on the
//!    simulator and an annotation-only side channel on the pool.
//! 2. **Zero perturbation.** Attaching a recorder changes nothing the
//!    run reports: a recorded `ServeReport` equals an unrecorded one
//!    field for field (bits, ticks, epochs, cache and rejection
//!    counters) — observability must never be a schedule input.
//! 3. **Honest truncation.** The bounded ring drops oldest-first with an
//!    explicit counter, and the recorder's counters stay mutually
//!    consistent with the report it narrates (satellite: per-kind
//!    rejection counts and `max_queue_depth` agree with the Reject /
//!    Admit events they were derived alongside).

use tdorch::exec::{Substrate, ThreadedCluster};
use tdorch::graph::flags::Flags;
use tdorch::graph::gen;
use tdorch::graph::ingest::DistGraph;
use tdorch::graph::spmd::{ingest_once, Placement, SpmdEngine};
use tdorch::mutate::{generate_mutations, MutationBatch, MutationConfig, MutationFeed};
use tdorch::obs::{EventKind, FlightRecorder, ObserverHandle};
use tdorch::serve::{QueryShard, RunOpts, ServeConfig, ServePolicy, ServeReport, Server};
use tdorch::workload::{
    generate_stream, hot_source_order, OpenLoopSource, Query, QueryKind, QueryMix, StreamConfig,
};
use tdorch::{Cluster, CostModel};

fn cost() -> CostModel {
    CostModel::paper_cluster()
}

fn serve_cfg() -> ServeConfig {
    ServeConfig { batch: 4, ..ServeConfig::default() }
}

/// Fusion and the cache both ON so every event kind is exercised.
fn serve_policy() -> ServePolicy {
    ServePolicy::new().with_fuse(true).with_cache(true)
}

fn stream_for(dg: &DistGraph, queries: usize, per_tick: usize, seed: u64) -> Vec<Query> {
    let hot = hot_source_order(&dg.out_deg);
    generate_stream(
        StreamConfig { queries, per_tick, every_ticks: 1, zipf_s: 1.5, mix: QueryMix::balanced() },
        &hot,
        seed,
    )
}

fn mutation_cfg() -> MutationConfig {
    MutationConfig {
        batches: 2,
        ops_per_batch: 6,
        insert_pct: 60,
        zipf_s: 1.2,
        start_tick: 2,
        every_ticks: 4,
    }
}

/// One recorded serving run on the given substrate, from a shared
/// placement.
fn run_recorded<B: Substrate>(
    sub: B,
    dg: DistGraph,
    cfg: ServeConfig,
    stream: &[Query],
    batches: Vec<MutationBatch>,
) -> (ServeReport, ObserverHandle) {
    let rec = FlightRecorder::shared(1 << 16);
    let mut server = Server::new(
        SpmdEngine::from_ingested(sub, dg, cost(), Flags::tdo_gp(), "obs-test", QueryShard::new),
        cfg,
    )
    .with_serving_policy(serve_policy());
    server.set_recorder(Some(rec.clone()));
    let mut feed = MutationFeed::new(batches);
    let report =
        server.serve(&mut OpenLoopSource::new(stream), RunOpts::new().feed(&mut feed));
    (report, rec)
}

#[test]
fn det_streams_are_bit_identical_across_backends() {
    let g = gen::barabasi_albert(400, 4, 11);
    for p in [1usize, 8] {
        let dg = ingest_once(&g, p, cost(), Placement::Spread);
        let stream = stream_for(&dg, 12, 2, 21);
        let (rep_s, rec_s) =
            run_recorded(Cluster::new(p, cost()), dg.clone(), serve_cfg(), &stream, Vec::new());
        let (rep_t, rec_t) =
            run_recorded(ThreadedCluster::new(p), dg, serve_cfg(), &stream, Vec::new());
        let (ss, st) =
            (rec_s.lock().unwrap().det_stream(), rec_t.lock().unwrap().det_stream());
        assert!(!ss.is_empty(), "P={p}: the recorder must see the run");
        assert_eq!(ss, st, "P={p}: deterministic streams must be bit-identical");
        // Both layers actually emitted into the one stream.
        assert!(ss.iter().any(|l| l.starts_with("Superstep")), "P={p}: substrate events");
        assert!(ss.iter().any(|l| l.starts_with("Admit")), "P={p}: admission events");
        assert!(ss.iter().any(|l| l.starts_with("BatchClose")), "P={p}: batch events");
        assert!(ss.iter().any(|l| l.starts_with("WaveDispatch")), "P={p}: wave events");
        assert!(ss.iter().any(|l| l.starts_with("QueryComplete")), "P={p}: completions");
        assert_eq!(rep_s.served(), rep_t.served(), "P={p}");
        assert_eq!(rep_s.served(), stream.len(), "default queue cap sheds nothing here");
    }
}

#[test]
fn mutating_streams_match_and_wall_stays_an_annotation() {
    let g = gen::barabasi_albert(400, 4, 13);
    for p in [1usize, 8] {
        let dg = ingest_once(&g, p, cost(), Placement::Spread);
        let stream = stream_for(&dg, 12, 2, 23);
        let hot = hot_source_order(&dg.out_deg);
        let batches = generate_mutations(mutation_cfg(), &g, &hot, 99);
        let (rep_s, rec_s) = run_recorded(
            Cluster::new(p, cost()),
            dg.clone(),
            serve_cfg(),
            &stream,
            batches.clone(),
        );
        let (rep_t, rec_t) =
            run_recorded(ThreadedCluster::new(p), dg, serve_cfg(), &stream, batches);
        let (rec_s, rec_t) = (rec_s.lock().unwrap(), rec_t.lock().unwrap());
        let ss = rec_s.det_stream();
        assert_eq!(ss, rec_t.det_stream(), "P={p}: mutating streams must match");
        assert!(ss.iter().any(|l| l.starts_with("MutationApply")), "P={p}: epoch bumps");
        assert_eq!(rep_s.graph_epoch, rep_t.graph_epoch, "P={p}");
        assert!(rep_s.graph_epoch >= 1, "P={p}: at least one batch must apply");
        // The simulator never annotates wall-clock...
        assert!(rec_s.events().all(|e| e.wall.is_none()), "P={p}: sim is wall-free");
        // ...while every threaded wave that follows engine supersteps
        // carries the per-machine busy delta since the last dispatch.
        let busy: Vec<_> = rec_t
            .events()
            .filter(|e| matches!(e.kind, EventKind::WaveDispatch { .. }))
            .filter_map(|e| e.wall.as_ref())
            .collect();
        assert!(!busy.is_empty(), "P={p}: threaded waves must carry busy annotations");
        assert!(busy.iter().all(|w| w.busy_ns.len() == p), "P={p}: one delta per machine");
    }
}

#[test]
fn recorder_off_and_on_serve_identical_reports() {
    let g = gen::barabasi_albert(400, 4, 17);
    let dg = ingest_once(&g, 2, cost(), Placement::Spread);
    let stream = stream_for(&dg, 12, 2, 29);
    let hot = hot_source_order(&dg.out_deg);
    let batches = generate_mutations(mutation_cfg(), &g, &hot, 31);

    let mut plain = Server::new(
        SpmdEngine::from_ingested(
            Cluster::new(2, cost()),
            dg.clone(),
            cost(),
            Flags::tdo_gp(),
            "obs-off",
            QueryShard::new,
        ),
        serve_cfg(),
    )
    .with_serving_policy(serve_policy());
    let mut off_feed = MutationFeed::new(batches.clone());
    let off =
        plain.serve(&mut OpenLoopSource::new(&stream), RunOpts::new().feed(&mut off_feed));
    let (on, _rec) = run_recorded(Cluster::new(2, cost()), dg, serve_cfg(), &stream, batches);

    // Every deterministic report field must be untouched by recording.
    assert_eq!(off.served(), on.served());
    assert_eq!(off.rejected, on.rejected);
    assert_eq!(off.rejected_by_kind, on.rejected_by_kind);
    assert_eq!(off.max_queue_depth, on.max_queue_depth);
    assert_eq!(off.batches, on.batches);
    assert_eq!(off.ticks, on.ticks);
    assert_eq!(off.graph_epoch, on.graph_epoch);
    assert_eq!(off.cache_hits, on.cache_hits);
    assert_eq!(off.cache_misses, on.cache_misses);
    assert_eq!(off.waves.len(), on.waves.len());
    assert_eq!(off.mutations.len(), on.mutations.len());
    for (a, b) in off.results.iter().zip(&on.results) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.bits, b.bits, "query {}: recording must not touch results", a.id);
        assert_eq!(a.wait_ticks, b.wait_ticks);
        assert_eq!(a.service_ticks, b.service_ticks);
        assert_eq!(a.batch, b.batch);
        assert_eq!(a.graph_epoch, b.graph_epoch);
        assert_eq!(a.cached, b.cached);
    }
}

#[test]
fn rejection_events_agree_with_the_report_counters() {
    let g = gen::barabasi_albert(400, 4, 19);
    let dg = ingest_once(&g, 2, cost(), Placement::Spread);
    // 6 arrivals/tick against a 2-deep queue forces shedding.
    let stream = stream_for(&dg, 24, 6, 37);
    let cfg = ServeConfig { queue_cap: 2, ..serve_cfg() };
    let (rep, rec) = run_recorded(Cluster::new(2, cost()), dg, cfg, &stream, Vec::new());
    assert!(rep.rejected > 0, "the overload must actually shed");
    assert_eq!(
        rep.rejected_by_kind.iter().sum::<u64>(),
        rep.rejected,
        "per-kind counts must partition the total"
    );

    let rec = rec.lock().unwrap();
    let mut rejects = 0u64;
    let mut by_kind = [0u64; 5];
    let mut max_depth = 0usize;
    for e in rec.events() {
        match &e.kind {
            EventKind::Reject { kind, .. } => {
                rejects += 1;
                by_kind[kind.index()] += 1;
            }
            EventKind::Admit { queue_depth, .. } => max_depth = max_depth.max(*queue_depth),
            _ => {}
        }
    }
    assert_eq!(rejects, rep.rejected, "one Reject event per shed query");
    assert_eq!(by_kind, rep.rejected_by_kind, "events and counters split alike");
    assert_eq!(max_depth, rep.max_queue_depth, "deepest Admit == max_queue_depth");

    // Spans reassemble the served lifecycles consistently with the report.
    let spans = rec.query_spans();
    for r in &rep.results {
        let s = spans
            .iter()
            .find(|s| s.query == r.id)
            .unwrap_or_else(|| panic!("served query {} must have a span", r.id));
        assert_eq!(s.wait_ticks, Some(r.wait_ticks), "query {}", r.id);
        assert_eq!(s.service_ticks, Some(r.service_ticks), "query {}", r.id);
        assert_eq!(s.cached, r.cached, "query {}", r.id);
        assert_eq!(s.batch, Some(r.batch), "query {}", r.id);
        assert!(s.queue_depth_at_admission.unwrap() <= cfg.queue_cap, "query {}", r.id);
    }
}

#[test]
fn ring_overflow_keeps_the_newest_with_an_explicit_counter() {
    let mut rec = FlightRecorder::with_capacity(3);
    for i in 0..8u64 {
        rec.record(EventKind::Admit { tick: i, query: i, kind: QueryKind::Bfs, queue_depth: 1 });
    }
    assert_eq!(rec.len(), 3, "the ring stays bounded");
    assert_eq!(rec.dropped(), 5, "loss is counted, never silent");
    assert_eq!(rec.recorded(), 8, "recorded() counts evicted events too");
    let queries: Vec<u64> = rec
        .events()
        .map(|e| match e.kind {
            EventKind::Admit { query, .. } => query,
            _ => unreachable!(),
        })
        .collect();
    assert_eq!(queries, vec![5, 6, 7], "oldest-first eviction keeps the newest tail");
}
