//! Hotspot-adaptive placement: the determinism and in-place-equality
//! contracts (PR 10 tentpole).
//!
//! 1. The adaptive serving loop is backend-invariant at P ∈ {1, 2, 8}:
//!    sim and threaded legs over the same drifting workload produce the
//!    identical decision log, the identical placement deltas, and
//!    bit-identical post-migration query results on the identical
//!    logical schedule.  (P = 1 degenerates to "no cold machine exists",
//!    so both backends must agree on *zero* decisions.)
//! 2. `SpmdEngine::apply_placement` patches the live engine into exactly
//!    the state a from-scratch engine reaches over the same assignment
//!    (`apply_to_distgraph` + `from_ingested`): block catalog, leaf
//!    sets, degrees, and all five query kinds' bits.
//! 3. No skew, no moves: on a balanced workload the controller (default
//!    policy) never fires, and riding a controller along changes nothing
//!    — schedule and bits equal the controller-free run.

use tdorch::exec::{Substrate, ThreadedCluster};
use tdorch::graph::flags::Flags;
use tdorch::graph::gen;
use tdorch::graph::ingest::DistGraph;
use tdorch::graph::spmd::{ingest_once, Placement, SpmdEngine};
use tdorch::mutate::{generate_mutations, MutationBatch, MutationConfig, MutationFeed};
use tdorch::place::{
    apply_to_distgraph, PlaceOp, PlacementController, PlacementDelta, PlacementPolicy,
};
use tdorch::serve::{QueryShard, RunOpts, ServeConfig, ServeReport, Server};
use tdorch::workload::{
    generate_stream, hot_source_order, OpenLoopSource, Query, QueryKind, QueryMix, StreamConfig,
};
use tdorch::{Cluster, CostModel};

const SEED: u64 = 11;

fn cost() -> CostModel {
    CostModel::paper_cluster()
}

/// PR-weighted mix: dense supersteps make the recorder's work signal
/// track resident arcs, which is what the drift skews.
fn drift_mix() -> QueryMix {
    QueryMix { bfs: 1, sssp: 1, pr: 4, cc: 1, bc: 1 }
}

fn drift_policy() -> PlacementPolicy {
    PlacementPolicy::default().with_trigger(1.02).with_max_moves(1).with_max_rounds(16)
}

/// Build the shared drifting workload: a small BA graph, a Zipf-hot
/// query stream, and an insert-heavy sharply-Zipf mutation feed that
/// piles arcs onto the hottest sources' owners.
fn drift_workload(p: usize) -> (DistGraph, Vec<Query>, Vec<MutationBatch>, ServeConfig) {
    let g = gen::barabasi_albert(600, 5, SEED);
    let dg = ingest_once(&g, p, cost(), Placement::Spread);
    let hot = hot_source_order(&dg.out_deg);
    let stream = generate_stream(
        StreamConfig { queries: 16, per_tick: 2, every_ticks: 1, zipf_s: 1.5, mix: drift_mix() },
        &hot,
        SEED.wrapping_add(1),
    );
    let batches = generate_mutations(
        MutationConfig {
            batches: 2,
            ops_per_batch: 400,
            insert_pct: 95,
            zipf_s: 2.5,
            start_tick: 2,
            every_ticks: 3,
        },
        &g,
        &hot,
        SEED.wrapping_add(2),
    );
    let cfg = ServeConfig {
        batch: 4,
        queue_cap: 16,
        work_per_tick: Some((g.m() as u64 / (p as u64 * 4)).max(64)),
        ..ServeConfig::default()
    };
    (dg, stream, batches, cfg)
}

/// One adaptive serving leg on the given substrate; returns the report
/// plus the controller's full decision trail.
fn adaptive_leg<B: Substrate>(
    sub: B,
    dg: DistGraph,
    stream: &[Query],
    batches: &[MutationBatch],
    cfg: ServeConfig,
    policy: PlacementPolicy,
) -> (ServeReport, Vec<String>, Vec<PlacementDelta>) {
    let mut server = Server::new(
        SpmdEngine::from_ingested(sub, dg, cost(), Flags::tdo_gp(), "placement-eq", QueryShard::new),
        cfg,
    );
    let mut feed = MutationFeed::new(batches.to_vec());
    let mut ctl = PlacementController::new(policy);
    let rep = server.serve(
        &mut OpenLoopSource::new(stream),
        RunOpts::new().feed(&mut feed).placement(&mut ctl),
    );
    (rep, ctl.decision_log().to_vec(), ctl.applied().to_vec())
}

#[test]
fn adaptive_serving_is_backend_invariant_at_p_1_2_8() {
    for p in [1usize, 2, 8] {
        let (dg, stream, batches, cfg) = drift_workload(p);
        let (sim_rep, sim_log, sim_deltas) = adaptive_leg(
            Cluster::new(p, cost()),
            dg.clone(),
            &stream,
            &batches,
            cfg,
            drift_policy(),
        );
        let (thr_rep, thr_log, thr_deltas) =
            adaptive_leg(ThreadedCluster::new(p), dg, &stream, &batches, cfg, drift_policy());

        assert_eq!(sim_log, thr_log, "P={p}: decision logs diverged across backends");
        assert_eq!(sim_deltas, thr_deltas, "P={p}: placement deltas diverged across backends");
        assert_eq!(sim_rep.ticks, thr_rep.ticks, "P={p}: logical span diverged");
        assert_eq!(sim_rep.served(), thr_rep.served(), "P={p}: served count diverged");
        assert_eq!(
            sim_rep.placements.len(),
            thr_rep.placements.len(),
            "P={p}: applied-round count diverged"
        );
        for (a, b) in sim_rep.placements.iter().zip(&thr_rep.placements) {
            assert_eq!(a.round, b.round, "P={p}: round ids diverged");
            assert_eq!(a.applied_tick, b.applied_tick, "P={p}: application ticks diverged");
            assert_eq!(a.ops, b.ops, "P={p}: applied ops diverged");
            assert_eq!(a.epoch_after, b.epoch_after, "P={p}: epochs diverged");
            assert_eq!(a.service_ticks, b.service_ticks, "P={p}: placement pricing diverged");
        }
        for (a, b) in sim_rep.results.iter().zip(&thr_rep.results) {
            assert_eq!(a.id, b.id, "P={p}: dispatch order diverged");
            assert_eq!(a.graph_epoch, b.graph_epoch, "P={p}: query {} epoch diverged", a.id);
            assert_eq!(a.bits, b.bits, "P={p}: query {} bits diverged", a.id);
        }
        match p {
            1 => {
                // One machine: there is never a colder peer to move to,
                // and both backends must agree on exactly that.
                assert!(sim_deltas.is_empty(), "P=1 must never migrate");
                assert_eq!(sim_rep.graph_epoch, batches.len() as u64);
            }
            _ => {
                assert!(
                    !sim_deltas.is_empty(),
                    "P={p}: the drift must trigger at least one migration round"
                );
                let post = sim_rep
                    .results
                    .iter()
                    .filter(|r| r.graph_epoch > batches.len() as u64)
                    .count();
                assert!(post > 0, "P={p}: some queries must run post-migration");
            }
        }
    }
}

#[test]
fn apply_placement_equals_from_scratch_engine_over_same_assignment() {
    let p = 2;
    let g = gen::barabasi_albert(400, 5, SEED);
    let dg = ingest_once(&g, p, cost(), Placement::Spread);
    let mut live = SpmdEngine::from_ingested(
        Cluster::new(p, cost()),
        dg.clone(),
        cost(),
        Flags::tdo_gp(),
        "placement-live",
        QueryShard::new,
    );

    // Hand-build one delta from the live catalog: split machine 0's
    // biggest block (replication of its read-hot source) and move the
    // biggest other-source block, both to machine 1.
    let catalog = live.block_catalog();
    let (split_slot, &(split_src, split_len)) = catalog[0]
        .iter()
        .enumerate()
        .max_by_key(|(_, (_, len))| *len)
        .expect("machine 0 holds blocks");
    assert!(split_len >= 2, "need a splittable block");
    let (move_slot, _) = catalog[0]
        .iter()
        .enumerate()
        .filter(|&(slot, &(src, len))| slot != split_slot && src != split_src && len > 0)
        .max_by_key(|&(_, &(_, len))| len)
        .expect("machine 0 holds a second source");
    let delta = PlacementDelta {
        round: 0,
        ops: vec![
            PlaceOp::Split {
                from: 0,
                block: split_slot as u32,
                at: (split_len / 2) as usize,
                to: 1,
            },
            PlaceOp::Move { from: 0, block: move_slot as u32, to: 1 },
        ],
    };

    live.apply_placement(&delta);
    assert_eq!(live.graph_epoch(), delta.ops.len() as u64, "one epoch bump per op");

    let mut replayed = dg.clone();
    apply_to_distgraph(&mut replayed, &delta);
    let fresh = SpmdEngine::from_ingested(
        Cluster::new(p, cost()),
        replayed,
        cost(),
        Flags::tdo_gp(),
        "placement-fresh",
        QueryShard::new,
    );

    assert_eq!(live.block_catalog(), fresh.block_catalog(), "catalogs diverged");
    let (lm, fm) = (live.meta(), fresh.meta());
    assert_eq!(lm.m, fm.m, "arc count diverged");
    assert_eq!(lm.out_deg, fm.out_deg, "degrees diverged");
    assert_eq!(lm.src_leaves, fm.src_leaves, "source leaf sets diverged");
    assert_eq!(lm.dst_leaves, fm.dst_leaves, "destination leaf sets diverged");

    // And the patched engine answers every kind bit-identically to the
    // from-scratch one — including through the moved and split blocks.
    let mut live_srv = Server::new(live, ServeConfig::default());
    let mut fresh_srv = Server::new(fresh, ServeConfig::default());
    for (id, kind) in
        [QueryKind::Bfs, QueryKind::Sssp, QueryKind::Pr, QueryKind::Cc, QueryKind::Bc]
            .into_iter()
            .enumerate()
    {
        for source in [split_src, 0, 17] {
            let q = Query { id: id as u64, kind, source, arrival: 0 };
            assert_eq!(
                live_srv.run_query(&q),
                fresh_srv.run_query(&q),
                "{kind:?} from {source}: bits diverged after in-place placement"
            );
        }
    }
}

#[test]
fn no_skew_means_zero_moves_and_an_untouched_schedule() {
    let p = 4;
    let g = gen::barabasi_albert(500, 5, SEED);
    let dg = ingest_once(&g, p, cost(), Placement::Spread);
    let hot = hot_source_order(&dg.out_deg);
    // Dense, balanced kinds only (PR/CC): with the spread ingestion and
    // no mutation drift, per-machine work stays within a few percent —
    // far under the default 1.25 trigger.
    let stream = generate_stream(
        StreamConfig {
            queries: 12,
            per_tick: 2,
            every_ticks: 1,
            zipf_s: 1.1,
            mix: QueryMix { bfs: 0, sssp: 0, pr: 2, cc: 1, bc: 0 },
        },
        &hot,
        SEED.wrapping_add(3),
    );
    let cfg = ServeConfig { batch: 4, queue_cap: 16, ..ServeConfig::default() };

    let (rep, log, deltas) = adaptive_leg(
        Cluster::new(p, cost()),
        dg.clone(),
        &stream,
        &[],
        cfg,
        PlacementPolicy::default(),
    );
    assert!(deltas.is_empty(), "balanced load must trigger zero moves (log: {log:?})");
    assert!(rep.placements.is_empty());
    assert_eq!(rep.graph_epoch, 0, "no placement, no epoch bump");

    // A controller that never fires is invisible: same schedule, same
    // bits as serving without one.
    let mut plain = Server::new(
        SpmdEngine::from_ingested(
            Cluster::new(p, cost()),
            dg,
            cost(),
            Flags::tdo_gp(),
            "placement-eq-plain",
            QueryShard::new,
        ),
        cfg,
    );
    let plain_rep = plain.serve(&mut OpenLoopSource::new(&stream), RunOpts::default());
    assert_eq!(rep.ticks, plain_rep.ticks, "an idle controller perturbed the clock");
    assert_eq!(rep.served(), plain_rep.served());
    for (a, b) in rep.results.iter().zip(&plain_rep.results) {
        assert_eq!(a.id, b.id, "an idle controller reordered dispatch");
        assert_eq!(a.bits, b.bits, "query {}: an idle controller changed bits", a.id);
    }
}
