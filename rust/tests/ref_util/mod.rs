//! Shared sequential reference solvers for the integration test crates
//! (the `benches/bench_util` pattern, for tests).
//!
//! Only references that are *verbatim identical* across suites live
//! here.  The suites deliberately keep their own, algorithmically
//! different oracles where diversity strengthens the check:
//! `graph_algorithms.rs` validates SSSP against heap Dijkstra and CC
//! against union-find, while `graph_exec_equivalence.rs` uses a
//! label-correcting SSSP and min-label-propagation CC whose f64
//! evaluation order is part of the bit-exactness argument — collapsing
//! those into one copy would make the suites validate against a single
//! (possibly wrong) oracle.

use tdorch::graph::{Graph, Vid};

/// Textbook queue BFS: hop distance from `src` per vertex (-1 =
/// unreachable).
pub fn bfs_ref(g: &Graph, src: Vid) -> Vec<i64> {
    let mut dist = vec![-1i64; g.n];
    dist[src as usize] = 0;
    let mut q = std::collections::VecDeque::from([src]);
    while let Some(u) = q.pop_front() {
        for (v, _) in g.neighbors(u) {
            if dist[*v as usize] < 0 {
                dist[*v as usize] = dist[u as usize] + 1;
                q.push_back(*v);
            }
        }
    }
    dist
}
