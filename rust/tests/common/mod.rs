//! Shared fixtures for integration tests: toy orchestration apps and a
//! small randomized property-test driver (proptest is unavailable offline;
//! this reproduces the idiom — many seeded random cases, first failing
//! case reported with its seed).

use tdorch::orchestration::{OrchApp, Task};
use tdorch::rng::Rng;

/// Additive counters: chunk = i64, ctx = increment. ⊗ = +, ⊙ = +=.
/// The canonical set-associative merge-able op (Def. 2 class ii) — one
/// definition, shared with the library's exec substrate fixtures.
/// ([`MaxApp`] below provides the value-*dependent* coverage.)
pub use tdorch::exec::apps::CounterApp;

/// Max-writer: chunk = u64, ctx = candidate, out = max. Idempotent
/// (Def. 2 class i) and exercises cross-address writes: each task reads
/// one chunk and writes `ctx % write_space`.
pub struct MaxApp;

impl OrchApp for MaxApp {
    type Ctx = u64;
    type Val = u64;
    type Out = u64;
    fn sigma(&self) -> u64 {
        2
    }
    fn chunk_words(&self) -> u64 {
        4
    }
    fn out_words(&self) -> u64 {
        1
    }
    fn execute(&self, ctx: &u64, val: &u64) -> Option<u64> {
        // Value-dependent output: wrong co-location changes the answer.
        Some(ctx.wrapping_add(*val))
    }
    fn combine(&self, a: u64, b: u64) -> u64 {
        a.max(b)
    }
    fn apply(&self, val: &mut u64, out: u64) {
        *val = (*val).max(out);
    }
}

/// Generate a random workload: `n` tasks over `addr_space` read addresses
/// with Zipf-ish skew (`skew` in [0,1]: 0 = uniform, 1 = all tasks hit
/// address 0), writing either in-place or to a random address.
pub fn random_tasks(
    rng: &mut Rng,
    n: usize,
    addr_space: u64,
    skew: f64,
    cross_writes: bool,
) -> Vec<Task<i64>> {
    (0..n)
        .map(|i| {
            let addr = if rng.next_f64() < skew {
                rng.next_below(4)
            } else {
                rng.next_below(addr_space)
            };
            let write = if cross_writes && rng.next_f64() < 0.5 {
                rng.next_below(addr_space)
            } else {
                addr
            };
            Task::new(addr, write, (i % 13) as i64 + 1)
        })
        .collect()
}

/// Tiny property-test driver: run `f` over `cases` seeds; panic with the
/// failing seed for reproduction.
pub fn for_seeds(cases: u64, f: impl Fn(u64)) {
    for seed in 0..cases {
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(seed)));
        if let Err(e) = result {
            eprintln!("property failed at seed {seed}");
            std::panic::resume_unwind(e);
        }
    }
}
